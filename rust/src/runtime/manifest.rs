//! artifacts/manifest.json parsing — the L2<->L3 ABI description.

use std::path::{Path, PathBuf};

use crate::config::ModelConfig;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{bail, err};

/// Element type at the artifact ABI boundary (f32/i32 only by design).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer (token ids, seeds).
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported manifest dtype {other}"),
        }
    }
}

/// Name/shape/dtype of one ABI tensor.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// ABI tensor name (e.g. `"w_qkv0"`, `"tokens"`, `"loss"`).
    pub name: String,
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: Dtype,
}

impl TensorSpec {
    /// Element count implied by the shape.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| err!("tensor spec missing name"))?
            .to_string();
        let shape = j
            .get("shape")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| err!("tensor spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| err!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(j.str_or("dtype", "f32"))?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One artifact's ABI description.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Unique artifact name (`train_<config>`, `fwd_<config>`, …).
    pub name: String,
    /// Artifact kind: `"init"` / `"train_step"` / `"fwd"` / `"probe"`.
    pub kind: String,
    /// HLO-text file relative to the manifest dir (empty for reference).
    pub file: String,
    /// The model config the artifact was lowered for, if any.
    pub config: Option<ModelConfig>,
    /// Input tensor specs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs, in return order.
    pub outputs: Vec<TensorSpec>,
}

/// A backend's artifact catalogue.
#[derive(Debug)]
pub struct Manifest {
    /// Directory the manifest (and artifact files) live in.
    pub dir: PathBuf,
    /// Every artifact the backend can execute.
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON text (artifact files resolve against `dir`).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| err!("manifest: {e}"))?;
        let arts = j
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| err!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let inputs = a
                .get("inputs")
                .and_then(|v| v.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(|v| v.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let config = a.get("config").and_then(ModelConfig::from_json);
            artifacts.push(ArtifactMeta {
                name: a.str_or("name", "").to_string(),
                kind: a.str_or("kind", "").to_string(),
                file: a.str_or("file", "").to_string(),
                config,
                inputs,
                outputs,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Artifact by exact name.
    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Artifact of `kind` for a model config (by canonical config name).
    pub fn find_for(&self, kind: &str, cfg: &ModelConfig) -> Option<&ArtifactMeta> {
        let want = format!("{}_{}", prefix_of(kind), cfg.name());
        self.artifacts.iter().find(|a| a.name == want)
    }

    /// All artifacts of one kind.
    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactMeta> + 'a {
        self.artifacts.iter().filter(move |a| a.kind == kind)
    }
}

fn prefix_of(kind: &str) -> &str {
    match kind {
        "train_step" => "train",
        "init" => "init",
        "fwd" => "fwd",
        "probe" => "probe",
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "train_mus_fp8_w64_d4_v512_s128_b4", "kind": "train_step",
         "file": "t.hlo.txt",
         "config": {"width": 64, "depth": 4, "head_dim": 16, "vocab": 512,
                    "seq_len": 128, "batch": 4, "ffn_ratio": 4, "d_base": 32,
                    "variant": "mus", "precision": "fp8",
                    "residual": "fixed", "activation": "gelu"},
         "inputs": [{"name": "embed", "shape": [512, 64], "dtype": "f32"},
                    {"name": "tokens", "shape": [4, 128], "dtype": "i32"}],
         "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.kind, "train_step");
        assert_eq!(a.inputs[0].shape, vec![512, 64]);
        assert_eq!(a.inputs[1].dtype, Dtype::I32);
        assert_eq!(a.outputs[0].elements(), 1);
        let cfg = a.config.as_ref().unwrap();
        assert_eq!(cfg.width, 64);
        assert_eq!(cfg.name(), "mus_fp8_w64_d4_v512_s128_b4");
    }

    #[test]
    fn find_for_matches_config() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let cfg = m.artifacts[0].config.clone().unwrap();
        assert!(m.find_for("train_step", &cfg).is_some());
        assert!(m.find_for("init", &cfg).is_none());
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "not json").is_err());
    }

    /// The real shipped manifest parses and is self-consistent.
    #[test]
    fn shipped_manifest_parses() {
        let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(m.artifacts.len() > 10);
        for a in &m.artifacts {
            assert!(!a.name.is_empty());
            assert!(dir.join(&a.file).exists(), "{} missing", a.file);
            if a.kind == "train_step" {
                let cfg = a.config.as_ref().expect("train artifact without config");
                // ABI: inputs = 2*nparams + tokens + lr + wd + tau
                assert_eq!(a.inputs.len(), a.outputs.len() + 2);
                let tok = &a.inputs[a.inputs.len() - 4];
                assert_eq!(tok.name, "tokens");
                assert_eq!(tok.shape, vec![cfg.batch, cfg.seq_len]);
                assert_eq!(a.name, format!("train_{}", cfg.name()));
            }
        }
    }
}
