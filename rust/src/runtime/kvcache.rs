//! Paged per-sequence KV cache for the incremental decode path.
//!
//! Storage is **BF16**: the attention operands are BF16-rounded by the
//! forward tower in every variant (see `runtime::block`), so caching
//! their upper 16 bits is lossless — a decode step reads back exactly
//! the f32 values a full-sequence forward would attend over, which is
//! what makes decode logits bit-identical to the training forward under
//! the static-FP8 and BF16 plans.
//!
//! Memory is **paged**: each (layer, head) chain of a sequence grows in
//! fixed [`SLAB_TOKENS`]-position slabs drawn from a shared [`KvPool`].
//! A slab holds that chain's K rows then V rows (`[k: T×dh][v: T×dh]`
//! BF16 bits). Slabs are recycled through a free list when sequences are
//! evicted — the pool is a ring of pages, so resident memory scales with
//! *live tokens* across sequences, not with `max_seq × n_sequences`.
//!
//! Positions are append-only per sequence: all `depth × heads` chains of
//! a sequence share one length counter ([`SeqKv::len`]), bumped once per
//! decoded token by [`SeqKv::advance`] after every layer has appended.

use crate::config::ModelConfig;
use crate::runtime::gemm::f32_to_bf16_bits;

/// Positions per slab. Small enough that a short sequence wastes little
/// (< `2·dh·SLAB_TOKENS` BF16 values per chain), large enough that page
/// chains stay short at the proxy context lengths.
pub(crate) const SLAB_TOKENS: usize = 32;

/// Bytes per stored cache value (BF16).
pub(crate) const KV_BYTES_PER_VALUE: usize = 2;

/// Bytes of KV cache READ by one decode token at context length `ctx`:
/// every layer's every head streams `ctx` K rows and `ctx` V rows of
/// `head_dim` BF16 values — `depth · 2 · ctx · width · 2` bytes. This is
/// the bandwidth term of the decode roofline; the perfmodel consumes it
/// and a test pins it to the `ModelConfig` closed form.
pub(crate) fn kv_bytes_read_per_token(cfg: &ModelConfig, ctx: usize) -> u64 {
    (cfg.depth * 2 * ctx * cfg.width * KV_BYTES_PER_VALUE) as u64
}

/// Bytes of KV cache WRITTEN per decoded token (one K row + one V row
/// per layer): `depth · 2 · width · 2`.
pub(crate) fn kv_bytes_written_per_token(cfg: &ModelConfig) -> u64 {
    (cfg.depth * 2 * cfg.width * KV_BYTES_PER_VALUE) as u64
}

/// Shared slab pool. One pool serves every sequence of an `InferSession`;
/// freed slabs are reused LIFO before any new allocation.
pub(crate) struct KvPool {
    dh: usize,
    n_chains: usize,
    slab_len: usize,
    slabs: Vec<Vec<u16>>,
    free: Vec<usize>,
}

/// One sequence's cache: per-(layer, head) slab chains plus the shared
/// position counter.
pub(crate) struct SeqKv {
    len: usize,
    /// `chains[layer * n_heads + head]` = ordered slab ids.
    chains: Vec<Vec<usize>>,
}

impl SeqKv {
    /// Cached positions (tokens whose K/V are fully appended).
    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Commit one appended token across all chains.
    pub(crate) fn advance(&mut self) {
        self.len += 1;
    }
}

impl KvPool {
    pub(crate) fn new(cfg: &ModelConfig) -> KvPool {
        KvPool {
            dh: cfg.head_dim,
            n_chains: cfg.depth * cfg.n_heads(),
            slab_len: 2 * SLAB_TOKENS * cfg.head_dim,
            slabs: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Fresh empty sequence (no slabs held until the first append).
    pub(crate) fn new_seq(&self) -> SeqKv {
        SeqKv { len: 0, chains: vec![Vec::new(); self.n_chains] }
    }

    /// Return every slab of `seq` to the free list (eviction).
    pub(crate) fn free_seq(&mut self, seq: &mut SeqKv) {
        for chain in &mut seq.chains {
            self.free.extend(chain.drain(..));
        }
        seq.len = 0;
    }

    /// Slabs currently held by live sequences.
    pub(crate) fn slabs_in_use(&self) -> usize {
        self.slabs.len() - self.free.len()
    }

    /// Bytes per slab (BF16 payload).
    pub(crate) fn slab_bytes(&self) -> usize {
        self.slab_len * KV_BYTES_PER_VALUE
    }

    fn alloc(&mut self) -> usize {
        if let Some(id) = self.free.pop() {
            return id;
        }
        self.slabs.push(vec![0u16; self.slab_len]);
        self.slabs.len() - 1
    }

    /// Append one position's K and V rows (`[dh]` f32, already
    /// BF16-rounded by the tower) to chain `(layer, head)` of `seq` at
    /// slot `slot`. Prefill appends slots `0..prompt_len` per chain;
    /// decode appends at `seq.len()`. The caller commits the position via
    /// [`SeqKv::advance`] (or [`KvPool::commit_prefill`]) once every
    /// layer has appended.
    pub(crate) fn append(
        &mut self,
        seq: &mut SeqKv,
        chain: usize,
        slot: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert_eq!(k_row.len(), self.dh);
        debug_assert_eq!(v_row.len(), self.dh);
        let (si, off) = (slot / SLAB_TOKENS, slot % SLAB_TOKENS);
        if seq.chains[chain].len() == si {
            let id = self.alloc();
            seq.chains[chain].push(id);
        }
        let slab = &mut self.slabs[seq.chains[chain][si]];
        let k_at = off * self.dh;
        let v_at = SLAB_TOKENS * self.dh + off * self.dh;
        for (dst, &v) in slab[k_at..k_at + self.dh].iter_mut().zip(k_row) {
            *dst = f32_to_bf16_bits(v);
        }
        for (dst, &v) in slab[v_at..v_at + self.dh].iter_mut().zip(v_row) {
            *dst = f32_to_bf16_bits(v);
        }
    }

    /// Commit a prefill of `n` positions (every chain already appended
    /// slots `0..n`).
    pub(crate) fn commit_prefill(&self, seq: &mut SeqKv, n: usize) {
        debug_assert_eq!(seq.len, 0, "prefill on a non-empty sequence");
        debug_assert!(seq.chains.iter().all(|c| c.len() == n.div_ceil(SLAB_TOKENS)));
        seq.len = n;
    }

    /// Append the K and V page slices of chain `(layer, head)` covering
    /// the first `len` positions, in order, onto `kp`/`vp` (the caller
    /// owns clearing — the decode path accumulates every
    /// (sequence, head) pair's pages into one flat per-layer list, so
    /// the hot loop allocates two Vecs per layer, not two per pair).
    /// Full slabs contribute `SLAB_TOKENS` rows; the kernel clips the
    /// final partial page to `len`.
    pub(crate) fn pages<'a>(
        &'a self,
        seq: &SeqKv,
        chain: usize,
        len: usize,
        kp: &mut Vec<&'a [u16]>,
        vp: &mut Vec<&'a [u16]>,
    ) {
        let n_slabs = len.div_ceil(SLAB_TOKENS);
        let half = SLAB_TOKENS * self.dh;
        for &id in &seq.chains[chain][..n_slabs] {
            let slab = &self.slabs[id];
            kp.push(&slab[..half]);
            vp.push(&slab[half..]);
        }
    }

    /// Chain index of `(layer, head)` given the model's head count.
    pub(crate) fn chain_of(&self, n_heads: usize, layer: usize, head: usize) -> usize {
        layer * n_heads + head
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::gemm::bf16_to_f32;

    fn cfg() -> ModelConfig {
        ModelConfig { width: 16, depth: 2, head_dim: 8, ..ModelConfig::default() }
    }

    #[test]
    fn append_and_read_back_round_trips_bf16() {
        let cfg = cfg();
        let mut pool = KvPool::new(&cfg);
        let mut seq = pool.new_seq();
        let dh = cfg.head_dim;
        // values on the BF16 grid: integers below 256 are exact (7-bit
        // mantissa), so the truncating store round-trips losslessly
        let mk = |base: f32| -> (Vec<f32>, Vec<f32>) {
            let k = (0..dh).map(|j| base + j as f32).collect();
            let v = (0..dh).map(|j| -base - j as f32).collect();
            (k, v)
        };
        let n = SLAB_TOKENS + 3; // spills into a second slab
        for slot in 0..n {
            for chain in 0..cfg.depth * cfg.n_heads() {
                let (k, v) = mk(slot as f32 + chain as f32 * 64.0);
                pool.append(&mut seq, chain, slot, &k, &v);
            }
        }
        pool.commit_prefill(&mut seq, n);
        assert_eq!(seq.len(), n);
        let (mut kp, mut vp) = (Vec::new(), Vec::new());
        let chain = pool.chain_of(cfg.n_heads(), 1, 1);
        pool.pages(&seq, chain, n, &mut kp, &mut vp);
        assert_eq!(kp.len(), 2);
        // row SLAB_TOKENS+2 lives at offset 2 of the second page
        let (k, v) = mk((SLAB_TOKENS + 2) as f32 + chain as f32 * 64.0);
        for j in 0..dh {
            assert_eq!(bf16_to_f32(kp[1][2 * dh + j]), k[j]);
            assert_eq!(bf16_to_f32(vp[1][2 * dh + j]), v[j]);
        }
    }

    #[test]
    fn pool_memory_scales_with_live_tokens_and_recycles_pages() {
        let cfg = cfg();
        let chains = cfg.depth * cfg.n_heads();
        let mut pool = KvPool::new(&cfg);
        let mut a = pool.new_seq();
        let row = vec![0f32; cfg.head_dim];
        for slot in 0..2 * SLAB_TOKENS {
            for c in 0..chains {
                pool.append(&mut a, c, slot, &row, &row);
            }
            a.advance();
        }
        // two slabs per chain, only for the tokens actually cached
        assert_eq!(pool.slabs_in_use(), 2 * chains);
        let peak = pool.slabs_in_use();
        // eviction returns every page ...
        pool.free_seq(&mut a);
        assert_eq!(pool.slabs_in_use(), 0);
        assert_eq!(a.len(), 0);
        // ... and a new sequence reuses them instead of growing the pool
        let mut b = pool.new_seq();
        for slot in 0..SLAB_TOKENS {
            for c in 0..chains {
                pool.append(&mut b, c, slot, &row, &row);
            }
            b.advance();
        }
        assert_eq!(pool.slabs_in_use(), chains);
        assert_eq!(pool.slabs.len(), peak, "pool grew despite free pages");
    }

    #[test]
    fn byte_accounting_matches_config_closed_forms() {
        let cfg = ModelConfig { width: 384, depth: 6, head_dim: 64, ..ModelConfig::default() };
        for ctx in [1usize, 17, 256] {
            assert_eq!(kv_bytes_read_per_token(&cfg, ctx), cfg.kv_cache_bytes_read_per_token(ctx));
        }
        assert_eq!(kv_bytes_written_per_token(&cfg), cfg.kv_cache_bytes_per_token());
        let pool = KvPool::new(&cfg);
        assert_eq!(pool.slab_bytes(), 2 * SLAB_TOKENS * cfg.head_dim * KV_BYTES_PER_VALUE);
    }
}
