//! Paged per-sequence KV cache for the incremental decode path.
//!
//! Storage is byte-addressed with two codecs ([`KvStoreMode`]):
//!
//!  - **BF16** (default): the attention operands are BF16-rounded by the
//!    forward tower in every variant (see `runtime::block`), so caching
//!    their upper 16 bits is lossless — a decode step reads back exactly
//!    the f32 values a full-sequence forward would attend over, which is
//!    what makes decode logits bit-identical to the training forward
//!    under the static-FP8 and BF16 plans.
//!  - **E4M3** (`KvStoreMode::Fp8E4m3`): one byte per value at one
//!    static per-(head, slab) scale. µS keeps K/V near unit RMS, so the
//!    static scale is 1.0 everywhere — no amax bookkeeping, exactly like
//!    the paper's training casts — and a per-slab
//!    [`crate::fp8::CastHealth`] record proves it (zero saturation under
//!    µS; asserted in tests and CI). Halves cache bytes; decode is no
//!    longer bit-identical, so callers bound the logit divergence
//!    instead (see `docs/SERVING.md`).
//!
//! Memory is **paged**: each (layer, head) chain of a sequence grows in
//! fixed [`SLAB_TOKENS`]-position slabs drawn from a shared [`KvPool`].
//! A slab holds that chain's K rows then V rows (`[k: T×dh][v: T×dh]`
//! encoded values). Slabs are **refcounted**: the prefix index
//! ([`PrefixIndex`]) lets requests sharing a prompt prefix share whole
//! slabs (copy-on-extend — a write into a shared slab first privatizes
//! it), and eviction returns a slab to the free list only when its last
//! holder drops. The pool is a ring of pages, so resident memory scales
//! with *live tokens* across sequences, not `max_seq × n_sequences`;
//! [`KvPool::trim`] additionally releases the backing memory of free
//! slabs between scheduler steps so one long-prompt burst no longer pins
//! peak memory forever (high-water vs current bytes are reported).
//!
//! Positions are append-only per sequence: all `depth × heads` chains of
//! a sequence share one length counter ([`SeqKv::len`]), bumped once per
//! decoded token by [`SeqKv::advance`] after every layer has appended.

use crate::config::ModelConfig;
use crate::fp8::{CastHealth, E4M3};
use crate::runtime::gemm::f32_to_bf16_bits;

/// Positions per slab. Small enough that a short sequence wastes little
/// (< `2·dh·SLAB_TOKENS` values per chain), large enough that page
/// chains stay short at the proxy context lengths.
pub(crate) const SLAB_TOKENS: usize = 32;

/// Bytes per stored cache value under the default BF16 codec.
pub(crate) const KV_BYTES_PER_VALUE: usize = 2;

/// KV-cache storage codec: how K/V rows are encoded into slab bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvStoreMode {
    /// Two bytes per value (BF16 bits, little-endian): lossless for the
    /// tower's BF16-rounded operands — decode stays bit-identical to the
    /// training forward.
    Bf16,
    /// One byte per value (E4M3 at static scale 1.0): half the cache
    /// bytes, twice the effective batch per pool; per-slab
    /// [`CastHealth`] proves the µS unit-variance contract holds.
    Fp8E4m3,
}

impl KvStoreMode {
    /// Bytes per stored cache value under this codec.
    pub fn bytes_per_value(self) -> usize {
        match self {
            KvStoreMode::Bf16 => KV_BYTES_PER_VALUE,
            KvStoreMode::Fp8E4m3 => 1,
        }
    }

    /// Stable label for reports and benches.
    pub fn label(self) -> &'static str {
        match self {
            KvStoreMode::Bf16 => "bf16",
            KvStoreMode::Fp8E4m3 => "fp8_e4m3",
        }
    }
}

/// Bytes of KV cache READ by one decode token at context length `ctx`
/// with `bpv` bytes per value: every layer's every head streams `ctx` K
/// rows and `ctx` V rows of `head_dim` values — `depth·2·ctx·width·bpv`.
/// This is the bandwidth term of the decode roofline; the perfmodel
/// consumes it and a test pins it to the `ModelConfig` closed form.
pub(crate) fn kv_bytes_read_per_token_at(cfg: &ModelConfig, ctx: usize, bpv: usize) -> u64 {
    (cfg.depth * 2 * ctx * cfg.width * bpv) as u64
}

/// BF16 specialization of [`kv_bytes_read_per_token_at`].
pub(crate) fn kv_bytes_read_per_token(cfg: &ModelConfig, ctx: usize) -> u64 {
    kv_bytes_read_per_token_at(cfg, ctx, KV_BYTES_PER_VALUE)
}

/// Bytes of KV cache WRITTEN per appended token (one K row + one V row
/// per layer) at `bpv` bytes per value: `depth·2·width·bpv`.
pub(crate) fn kv_bytes_written_per_token_at(cfg: &ModelConfig, bpv: usize) -> u64 {
    (cfg.depth * 2 * cfg.width * bpv) as u64
}

/// BF16 specialization of [`kv_bytes_written_per_token_at`].
pub(crate) fn kv_bytes_written_per_token(cfg: &ModelConfig) -> u64 {
    kv_bytes_written_per_token_at(cfg, KV_BYTES_PER_VALUE)
}

/// FNV-1a over a token chain (little-endian token bytes) — the prefix
/// index's chain hash. Deterministic and seedless by design: the
/// determinism-contract linter bans randomized hash state in kernel
/// files, and an unseeded fold keeps lookups reproducible across runs.
pub(crate) fn prefix_hash(tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Shared refcounted slab pool. One pool serves every sequence of an
/// `InferSession`; freed slabs are reused LIFO before any new allocation.
pub(crate) struct KvPool {
    dh: usize,
    n_chains: usize,
    /// Encoded values per slab (K half + V half).
    slab_values: usize,
    mode: KvStoreMode,
    slabs: Vec<Vec<u8>>,
    /// Holders per slab id (sequences + prefix-index entries). 0 ⇒ free.
    refs: Vec<u32>,
    /// Static per-slab cast scale (µS: 1.0 everywhere; see module docs).
    scales: Vec<f32>,
    /// Per-slab FP8 cast health of the rows encoded into it (the
    /// per-(head, slab) proof that the static scale saturates nothing).
    health: Vec<CastHealth>,
    /// Materialized free slabs (buffer retained, ready for reuse).
    free: Vec<usize>,
    /// Trimmed free slabs (buffer released; id stays valid).
    parked: Vec<usize>,
    bytes_written: u64,
    high_water_bytes: usize,
    fp8_health_total: CastHealth,
}

/// One sequence's cache: per-(layer, head) slab chains plus the shared
/// position counter.
pub(crate) struct SeqKv {
    len: usize,
    /// `chains[layer * n_heads + head]` = ordered slab ids.
    chains: Vec<Vec<usize>>,
}

impl SeqKv {
    /// Cached positions (tokens whose K/V are fully appended).
    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Commit one appended token across all chains.
    pub(crate) fn advance(&mut self) {
        self.len += 1;
    }
}

impl KvPool {
    pub(crate) fn new(cfg: &ModelConfig) -> KvPool {
        KvPool::new_with_mode(cfg, KvStoreMode::Bf16)
    }

    pub(crate) fn new_with_mode(cfg: &ModelConfig, mode: KvStoreMode) -> KvPool {
        KvPool {
            dh: cfg.head_dim,
            n_chains: cfg.depth * cfg.n_heads(),
            slab_values: 2 * SLAB_TOKENS * cfg.head_dim,
            mode,
            slabs: Vec::new(),
            refs: Vec::new(),
            scales: Vec::new(),
            health: Vec::new(),
            free: Vec::new(),
            parked: Vec::new(),
            bytes_written: 0,
            high_water_bytes: 0,
            fp8_health_total: CastHealth::default(),
        }
    }

    pub(crate) fn mode(&self) -> KvStoreMode {
        self.mode
    }

    /// Bytes per stored cache value under the pool's codec.
    pub(crate) fn bytes_per_value(&self) -> usize {
        self.mode.bytes_per_value()
    }

    /// Fresh empty sequence (no slabs held until the first append).
    pub(crate) fn new_seq(&self) -> SeqKv {
        SeqKv { len: 0, chains: vec![Vec::new(); self.n_chains] }
    }

    /// Drop `seq`'s hold on every slab (eviction); slabs whose last
    /// holder this was return to the free list.
    pub(crate) fn free_seq(&mut self, seq: &mut SeqKv) {
        for chain in 0..seq.chains.len() {
            while let Some(id) = seq.chains[chain].pop() {
                self.release(id);
            }
        }
        seq.len = 0;
    }

    /// Slabs currently held by live sequences or prefix-index entries.
    pub(crate) fn slabs_in_use(&self) -> usize {
        self.slabs.len() - self.free.len() - self.parked.len()
    }

    /// Slabs whose backing buffer is resident (in use + free-but-kept).
    pub(crate) fn materialized_slabs(&self) -> usize {
        self.slabs.len() - self.parked.len()
    }

    /// Resident cache bytes (in-use + free-but-materialized payloads).
    pub(crate) fn materialized_bytes(&self) -> usize {
        self.materialized_slabs() * self.slab_bytes()
    }

    /// Largest resident byte footprint the pool ever reached.
    pub(crate) fn high_water_bytes(&self) -> usize {
        self.high_water_bytes
    }

    /// Total bytes encoded into slabs by [`KvPool::append`].
    pub(crate) fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    /// Cumulative cast health of every FP8 KV append (empty under BF16).
    pub(crate) fn fp8_health(&self) -> CastHealth {
        self.fp8_health_total
    }

    /// Live slabs whose per-slab FP8 health recorded any saturation —
    /// the per-(head, slab) witness that static scale 1.0 holds (µS: 0).
    pub(crate) fn fp8_saturated_slabs(&self) -> usize {
        (0..self.slabs.len())
            .filter(|&id| self.refs[id] > 0 && self.health[id].saturated > 0)
            .count()
    }

    /// Bytes per slab under the pool's codec.
    pub(crate) fn slab_bytes(&self) -> usize {
        self.slab_values * self.bytes_per_value()
    }

    /// Release the backing memory of free slabs until at most
    /// `target_slabs` buffers stay materialized (never touches in-use
    /// slabs, so the reachable floor is `slabs_in_use()`). Ids remain
    /// valid — a later alloc rematerializes a parked slab zero-filled.
    pub(crate) fn trim(&mut self, target_slabs: usize) {
        while self.materialized_slabs() > target_slabs {
            let Some(id) = self.free.pop() else { break };
            self.slabs[id] = Vec::new();
            self.parked.push(id);
        }
    }

    fn retain(&mut self, id: usize) {
        self.refs[id] += 1;
    }

    fn release(&mut self, id: usize) {
        debug_assert!(self.refs[id] > 0, "release of a free slab {id}");
        self.refs[id] -= 1;
        if self.refs[id] == 0 {
            self.free.push(id);
        }
    }

    fn alloc(&mut self) -> usize {
        let id = if let Some(id) = self.free.pop() {
            id
        } else if let Some(id) = self.parked.pop() {
            self.slabs[id] = vec![0u8; self.slab_bytes()];
            id
        } else {
            self.slabs.push(vec![0u8; self.slab_bytes()]);
            self.refs.push(0);
            self.scales.push(1.0);
            self.health.push(CastHealth::default());
            self.slabs.len() - 1
        };
        self.refs[id] = 1;
        self.scales[id] = 1.0;
        self.health[id] = CastHealth::default();
        self.high_water_bytes = self.high_water_bytes.max(self.materialized_bytes());
        id
    }

    /// Copy the first `rows` positions of both halves of `src` into a
    /// fresh slab (the partial-tail copy of prefix adoption). Returns the
    /// new slab id; bytes beyond `rows` stay zero/stale and are never
    /// read (page gathers clip to the sequence length).
    fn copy_rows_into_fresh(&mut self, src: usize, rows: usize) -> usize {
        let nid = self.alloc();
        debug_assert_ne!(src, nid, "alloc returned a live slab");
        let bpv = self.bytes_per_value();
        let half = SLAB_TOKENS * self.dh * bpv;
        let n = rows * self.dh * bpv;
        let (src_buf, dst_buf): (&[u8], &mut Vec<u8>) = if src < nid {
            let (l, r) = self.slabs.split_at_mut(nid);
            (&l[src], &mut r[0])
        } else {
            let (l, r) = self.slabs.split_at_mut(src);
            (&r[0], &mut l[nid])
        };
        dst_buf[..n].copy_from_slice(&src_buf[..n]);
        dst_buf[half..half + n].copy_from_slice(&src_buf[half..half + n]);
        self.scales[nid] = self.scales[src];
        self.health[nid] = self.health[src];
        nid
    }

    /// Full-slab copy (copy-on-extend: privatize a shared slab before a
    /// write). Returns the new slab id.
    fn copy_full_slab(&mut self, src: usize) -> usize {
        self.copy_rows_into_fresh(src, SLAB_TOKENS)
    }

    /// Encode one `[dh]` f32 row into slab bytes at value offset `at`.
    fn encode_row(&mut self, id: usize, at: usize, row: &[f32]) {
        let bpv = self.bytes_per_value();
        let base = at * bpv;
        match self.mode {
            KvStoreMode::Bf16 => {
                let slab = &mut self.slabs[id];
                for (j, &v) in row.iter().enumerate() {
                    let b = f32_to_bf16_bits(v).to_le_bytes();
                    slab[base + 2 * j] = b[0];
                    slab[base + 2 * j + 1] = b[1];
                }
            }
            KvStoreMode::Fp8E4m3 => {
                let scale = self.scales[id];
                let h = E4M3.cast_health(row, scale);
                let slab = &mut self.slabs[id];
                for (j, &v) in row.iter().enumerate() {
                    slab[base + j] = E4M3.encode(v * scale) as u8;
                }
                self.health[id].merge(&h);
                self.fp8_health_total.merge(&h);
            }
        }
        self.bytes_written += (row.len() * bpv) as u64;
    }

    /// Append one position's K and V rows (`[dh]` f32, already
    /// BF16-rounded by the tower) to chain `(layer, head)` of `seq` at
    /// slot `slot`. Prefill appends slots `0..prompt_len` per chain;
    /// decode appends at `seq.len()`. A shared target slab (refcount > 1,
    /// i.e. also held by the prefix index or another sequence) is
    /// privatized first — copy-on-extend. The caller commits the position
    /// via [`SeqKv::advance`] (or [`KvPool::commit_prefill`]) once every
    /// layer has appended.
    pub(crate) fn append(
        &mut self,
        seq: &mut SeqKv,
        chain: usize,
        slot: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert_eq!(k_row.len(), self.dh);
        debug_assert_eq!(v_row.len(), self.dh);
        let (si, off) = (slot / SLAB_TOKENS, slot % SLAB_TOKENS);
        if seq.chains[chain].len() == si {
            let id = self.alloc();
            seq.chains[chain].push(id);
        }
        let mut id = seq.chains[chain][si];
        if self.refs[id] > 1 {
            let nid = self.copy_full_slab(id);
            self.release(id);
            seq.chains[chain][si] = nid;
            id = nid;
        }
        self.encode_row(id, off * self.dh, k_row);
        self.encode_row(id, SLAB_TOKENS * self.dh + off * self.dh, v_row);
    }

    /// Commit a prefill of `n` positions (every chain already appended
    /// slots `0..n`).
    pub(crate) fn commit_prefill(&self, seq: &mut SeqKv, n: usize) {
        debug_assert_eq!(seq.len, 0, "prefill on a non-empty sequence");
        debug_assert!(seq.chains.iter().all(|c| c.len() == n.div_ceil(SLAB_TOKENS)));
        seq.len = n;
    }

    /// Append the K and V page slices of chain `(layer, head)` covering
    /// the first `len` positions, in order, onto `kp`/`vp` (the caller
    /// owns clearing — the decode path accumulates every
    /// (sequence, head) pair's pages into one flat per-layer list, so
    /// the hot loop allocates two Vecs per layer, not two per pair).
    /// Full slabs contribute `SLAB_TOKENS` rows; the kernel clips the
    /// final partial page to `len`.
    pub(crate) fn pages<'a>(
        &'a self,
        seq: &SeqKv,
        chain: usize,
        len: usize,
        kp: &mut Vec<&'a [u8]>,
        vp: &mut Vec<&'a [u8]>,
    ) {
        let n_slabs = len.div_ceil(SLAB_TOKENS);
        let half = SLAB_TOKENS * self.dh * self.bytes_per_value();
        for &id in &seq.chains[chain][..n_slabs] {
            debug_assert_eq!(self.scales[id], 1.0, "µS static KV scale contract");
            let slab = &self.slabs[id];
            kp.push(&slab[..half]);
            vp.push(&slab[half..]);
        }
    }

    /// Chain index of `(layer, head)` given the model's head count.
    pub(crate) fn chain_of(&self, n_heads: usize, layer: usize, head: usize) -> usize {
        layer * n_heads + head
    }
}

// ---------------------------------------------------------------------------
// Prefix index

/// One cached prompt prefix: its token chain, the chain hashes at every
/// full-slab boundary, and a refcounted hold on the slabs covering it.
struct PrefixEntry {
    /// `hashes[i]` = [`prefix_hash`] of `tokens[..(i+1)·SLAB_TOKENS]`.
    hashes: Vec<u64>,
    tokens: Vec<i32>,
    /// Per-chain slab ids covering `tokens.len()` positions.
    chains: Vec<Vec<usize>>,
}

/// Hash-keyed prompt-prefix index over a [`KvPool`].
///
/// Lookup finds the longest cached prefix of a prompt: the chain hashes
/// give the longest full-slab-aligned candidate in O(slabs), a token
/// compare verifies it (collisions can shorten a match, never corrupt
/// one), and a token-wise extension walks into the entry's partial tail
/// slab. Adoption shares the full slabs by refcount and copies only the
/// partial tail ([`KvPool::copy_rows_into_fresh`]); the match is capped
/// at `prompt_len − 1` so the admission pass always computes at least
/// the last position's logits itself.
///
/// Entries are held in insertion order and evicted FIFO at `capacity` —
/// deterministic, no clocks, no LRU state (the linter bans wall-clock
/// reads in kernel files).
pub(crate) struct PrefixIndex {
    entries: Vec<PrefixEntry>,
    capacity: usize,
}

impl PrefixIndex {
    pub(crate) fn new(capacity: usize) -> PrefixIndex {
        PrefixIndex { entries: Vec::new(), capacity }
    }

    /// Cached prefixes currently held.
    pub(crate) fn len(&self) -> usize {
        self.entries.len()
    }

    /// Longest cached prefix of `tokens`: `(entry, matched_tokens)` with
    /// `matched_tokens ≥ 1`, or `None`. Capped at `tokens.len() − 1`.
    pub(crate) fn lookup(&self, tokens: &[i32]) -> Option<(usize, usize)> {
        let cap = tokens.len().saturating_sub(1);
        if cap == 0 {
            return None;
        }
        // prompt chain hashes at each full-slab boundary within the cap
        let n_bounds = cap / SLAB_TOKENS;
        let mut bounds = Vec::with_capacity(n_bounds);
        for i in 0..n_bounds {
            bounds.push(prefix_hash(&tokens[..(i + 1) * SLAB_TOKENS]));
        }
        let mut best: Option<(usize, usize)> = None;
        for (ei, e) in self.entries.iter().enumerate() {
            // longest boundary where the chain hashes agree
            let mut m = 0usize;
            for i in 0..n_bounds.min(e.hashes.len()) {
                if e.hashes[i] == bounds[i] {
                    m = (i + 1) * SLAB_TOKENS;
                } else {
                    break;
                }
            }
            // verify (hash collisions shorten, never corrupt), then
            // extend token-wise into the partial tail
            while m > 0 && e.tokens[..m] != tokens[..m] {
                m = (m / SLAB_TOKENS - 1) * SLAB_TOKENS;
            }
            let lim = cap.min(e.tokens.len());
            while m < lim && e.tokens[m] == tokens[m] {
                m += 1;
            }
            let bm = best.map(|(_, bm)| bm).unwrap_or(0);
            if m > bm {
                best = Some((ei, m));
            }
        }
        best
    }

    /// Populate empty `seq` with the first `m` positions of `entry`:
    /// full slabs are shared by refcount, the partial tail (if any) is
    /// copied into a private slab. Returns the bytes copied.
    pub(crate) fn adopt(
        &self,
        entry: usize,
        m: usize,
        pool: &mut KvPool,
        seq: &mut SeqKv,
    ) -> u64 {
        debug_assert_eq!(seq.len, 0, "prefix adoption on a non-empty sequence");
        let e = &self.entries[entry];
        debug_assert!(m <= e.tokens.len());
        let (full, tail) = (m / SLAB_TOKENS, m % SLAB_TOKENS);
        let mut copied = 0u64;
        for chain in 0..e.chains.len() {
            for i in 0..full {
                let id = e.chains[chain][i];
                pool.retain(id);
                seq.chains[chain].push(id);
            }
            if tail > 0 {
                let nid = pool.copy_rows_into_fresh(e.chains[chain][full], tail);
                copied += (2 * tail * pool.dh * pool.bytes_per_value()) as u64;
                seq.chains[chain].push(nid);
            }
        }
        seq.len = m;
        copied
    }

    /// Index the first `tokens.len()` positions of `seq` (its prompt)
    /// under the token chain `tokens`, taking a refcount hold on every
    /// covering slab. Duplicate token chains are not re-inserted; at
    /// capacity the oldest entry is evicted first (FIFO).
    pub(crate) fn insert(&mut self, tokens: &[i32], pool: &mut KvPool, seq: &SeqKv) {
        if self.capacity == 0 || tokens.is_empty() {
            return;
        }
        debug_assert!(seq.len >= tokens.len(), "prompt not fully cached at insert");
        if self.entries.iter().any(|e| e.tokens == tokens) {
            return;
        }
        while self.entries.len() >= self.capacity {
            let e = self.entries.remove(0);
            for chain in &e.chains {
                for &id in chain {
                    pool.release(id);
                }
            }
        }
        let n_slabs = tokens.len().div_ceil(SLAB_TOKENS);
        let mut chains = Vec::with_capacity(seq.chains.len());
        for chain in &seq.chains {
            for &id in &chain[..n_slabs] {
                pool.retain(id);
            }
            chains.push(chain[..n_slabs].to_vec());
        }
        let hashes = (0..tokens.len() / SLAB_TOKENS)
            .map(|i| prefix_hash(&tokens[..(i + 1) * SLAB_TOKENS]))
            .collect();
        self.entries.push(PrefixEntry { hashes, tokens: tokens.to_vec(), chains });
    }

    /// Drop every entry, releasing its slab holds.
    pub(crate) fn clear(&mut self, pool: &mut KvPool) {
        while let Some(e) = self.entries.pop() {
            for chain in &e.chains {
                for &id in chain {
                    pool.release(id);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::gemm::bf16_to_f32;

    fn cfg() -> ModelConfig {
        ModelConfig { width: 16, depth: 2, head_dim: 8, ..ModelConfig::default() }
    }

    fn read_k(pool: &KvPool, seq: &SeqKv, chain: usize, len: usize, row: usize) -> Vec<f32> {
        let (mut kp, mut vp) = (Vec::new(), Vec::new());
        pool.pages(seq, chain, len, &mut kp, &mut vp);
        let bpv = pool.bytes_per_value();
        let page = &kp[row / SLAB_TOKENS];
        let at = (row % SLAB_TOKENS) * pool.dh * bpv;
        let mut out = vec![0f32; pool.dh];
        crate::runtime::gemm::decode_kv_bytes(
            match pool.mode() {
                KvStoreMode::Bf16 => crate::runtime::gemm::KvCodec::Bf16,
                KvStoreMode::Fp8E4m3 => unreachable!("bf16 helper"),
            },
            &page[at..at + pool.dh * bpv],
            &mut out,
        );
        out
    }

    #[test]
    fn append_and_read_back_round_trips_bf16() {
        let cfg = cfg();
        let mut pool = KvPool::new(&cfg);
        let mut seq = pool.new_seq();
        let dh = cfg.head_dim;
        // values on the BF16 grid: integers below 256 are exact (7-bit
        // mantissa), so the truncating store round-trips losslessly
        let mk = |base: f32| -> (Vec<f32>, Vec<f32>) {
            let k = (0..dh).map(|j| base + j as f32).collect();
            let v = (0..dh).map(|j| -base - j as f32).collect();
            (k, v)
        };
        let n = SLAB_TOKENS + 3; // spills into a second slab
        for slot in 0..n {
            for chain in 0..cfg.depth * cfg.n_heads() {
                let (k, v) = mk(slot as f32 + chain as f32 * 64.0);
                pool.append(&mut seq, chain, slot, &k, &v);
            }
        }
        pool.commit_prefill(&mut seq, n);
        assert_eq!(seq.len(), n);
        let (mut kp, mut vp) = (Vec::new(), Vec::new());
        let chain = pool.chain_of(cfg.n_heads(), 1, 1);
        pool.pages(&seq, chain, n, &mut kp, &mut vp);
        assert_eq!(kp.len(), 2);
        // row SLAB_TOKENS+2 lives at offset 2 of the second page
        let (k, v) = mk((SLAB_TOKENS + 2) as f32 + chain as f32 * 64.0);
        for j in 0..dh {
            let at = (2 * dh + j) * 2;
            let kb = u16::from_le_bytes([kp[1][at], kp[1][at + 1]]);
            let vb = u16::from_le_bytes([vp[1][at], vp[1][at + 1]]);
            assert_eq!(bf16_to_f32(kb), k[j]);
            assert_eq!(bf16_to_f32(vb), v[j]);
        }
        assert_eq!(pool.bytes_written(), (n * cfg.depth * cfg.n_heads() * 2 * dh * 2) as u64);
    }

    #[test]
    fn pool_memory_scales_with_live_tokens_and_recycles_pages() {
        let cfg = cfg();
        let chains = cfg.depth * cfg.n_heads();
        let mut pool = KvPool::new(&cfg);
        let mut a = pool.new_seq();
        let row = vec![0f32; cfg.head_dim];
        for slot in 0..2 * SLAB_TOKENS {
            for c in 0..chains {
                pool.append(&mut a, c, slot, &row, &row);
            }
            a.advance();
        }
        // two slabs per chain, only for the tokens actually cached
        assert_eq!(pool.slabs_in_use(), 2 * chains);
        let peak = pool.slabs_in_use();
        assert_eq!(pool.high_water_bytes(), peak * pool.slab_bytes());
        // eviction returns every page ...
        pool.free_seq(&mut a);
        assert_eq!(pool.slabs_in_use(), 0);
        assert_eq!(a.len(), 0);
        // ... and a new sequence reuses them instead of growing the pool
        let mut b = pool.new_seq();
        for slot in 0..SLAB_TOKENS {
            for c in 0..chains {
                pool.append(&mut b, c, slot, &row, &row);
            }
            b.advance();
        }
        assert_eq!(pool.slabs_in_use(), chains);
        assert_eq!(pool.slabs.len(), peak, "pool grew despite free pages");
    }

    #[test]
    fn trim_releases_free_buffers_and_alloc_rematerializes() {
        let cfg = cfg();
        let chains = cfg.depth * cfg.n_heads();
        let mut pool = KvPool::new(&cfg);
        let mut a = pool.new_seq();
        let row = vec![1.5f32; cfg.head_dim];
        for slot in 0..3 * SLAB_TOKENS {
            for c in 0..chains {
                pool.append(&mut a, c, slot, &row, &row);
            }
            a.advance();
        }
        let peak_bytes = pool.materialized_bytes();
        pool.free_seq(&mut a);
        // all free but still materialized — trim to one slab's worth
        assert_eq!(pool.materialized_bytes(), peak_bytes);
        pool.trim(1);
        assert_eq!(pool.materialized_slabs(), 1);
        assert_eq!(pool.materialized_bytes(), pool.slab_bytes());
        assert_eq!(pool.high_water_bytes(), peak_bytes, "high-water survives trim");
        // a new sequence rematerializes parked slabs zero-filled and
        // round-trips writes as usual
        let mut b = pool.new_seq();
        for slot in 0..2 * SLAB_TOKENS {
            for c in 0..chains {
                pool.append(&mut b, c, slot, &row, &row);
            }
            b.advance();
        }
        assert_eq!(pool.slabs_in_use(), 2 * chains);
        assert_eq!(read_k(&pool, &b, 0, b.len(), SLAB_TOKENS + 1), vec![1.5f32; cfg.head_dim]);
        // trim cannot touch in-use slabs
        pool.trim(0);
        assert_eq!(pool.materialized_slabs(), pool.slabs_in_use());
    }

    #[test]
    fn fp8_mode_halves_slab_bytes_and_tracks_health() {
        let cfg = cfg();
        let bf16 = KvPool::new(&cfg);
        let mut pool = KvPool::new_with_mode(&cfg, KvStoreMode::Fp8E4m3);
        assert_eq!(pool.slab_bytes() * 2, bf16.slab_bytes());
        assert_eq!(KvStoreMode::Fp8E4m3.bytes_per_value(), 1);
        let mut seq = pool.new_seq();
        // unit-scale values: representable band of E4M3, zero saturation
        let k: Vec<f32> = (0..cfg.head_dim).map(|j| 0.25 + j as f32 * 0.125).collect();
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        for c in 0..cfg.depth * cfg.n_heads() {
            pool.append(&mut seq, c, 0, &k, &v);
        }
        pool.commit_prefill(&mut seq, 1);
        let h = pool.fp8_health();
        assert_eq!(h.total, (cfg.depth * cfg.n_heads() * 2 * cfg.head_dim) as u64);
        assert_eq!(h.saturated, 0);
        assert_eq!(pool.fp8_saturated_slabs(), 0);
        // read back through the FP8 codec: exactly decode(encode(x))
        let lut = E4M3.decode_lut8();
        let (mut kp, mut vp) = (Vec::new(), Vec::new());
        pool.pages(&seq, 0, 1, &mut kp, &mut vp);
        for (j, &want) in k.iter().enumerate() {
            let got = lut[kp[0][j] as usize];
            assert_eq!(got, E4M3.decode(E4M3.encode(want)));
            assert_eq!(got, want, "quarter-steps are exact in E4M3");
        }
        // out-of-band values do register saturation per slab
        let big = vec![1e6f32; cfg.head_dim];
        pool.append(&mut seq, 0, 1, &big, &big);
        assert!(pool.fp8_health().saturated > 0);
        assert_eq!(pool.fp8_saturated_slabs(), 1);
    }

    #[test]
    fn prefix_index_shares_full_slabs_and_copies_tails() {
        let cfg = cfg();
        let chains = cfg.depth * cfg.n_heads();
        let mut pool = KvPool::new(&cfg);
        let mut index = PrefixIndex::new(4);
        let dh = cfg.head_dim;
        let prompt: Vec<i32> = (0..SLAB_TOKENS as i32 + 10).collect();
        // donor: cache the prompt, then index it
        let mut donor = pool.new_seq();
        for (slot, &t) in prompt.iter().enumerate() {
            let row: Vec<f32> = (0..dh).map(|j| t as f32 + j as f32 * 0.5).collect();
            for c in 0..chains {
                pool.append(&mut donor, c, slot, &row, &row);
            }
        }
        pool.commit_prefill(&mut donor, prompt.len());
        index.insert(&prompt, &mut pool, &donor);
        let held = pool.slabs_in_use();

        // a longer prompt sharing the whole indexed prefix
        let mut longer = prompt.clone();
        longer.extend([901, 902, 903]);
        let (e, m) = index.lookup(&longer).unwrap();
        assert_eq!(m, prompt.len(), "full indexed prefix matches");
        let mut adopter = pool.new_seq();
        let copied = index.adopt(e, m, &mut pool, &mut adopter);
        assert_eq!(adopter.len(), prompt.len());
        // full slab shared (same id), partial tail privately copied
        assert_eq!(adopter.chains[0][0], donor.chains[0][0]);
        assert_ne!(adopter.chains[0][1], donor.chains[0][1]);
        assert_eq!(copied, (chains * 2 * 10 * dh * 2) as u64);
        // shared rows read back identically (bitwise)
        for row in [0usize, SLAB_TOKENS - 1, SLAB_TOKENS + 9] {
            assert_eq!(
                read_k(&pool, &adopter, 1, adopter.len(), row),
                read_k(&pool, &donor, 1, donor.len(), row),
                "row {row}"
            );
        }

        // evicting the donor must not free slabs the index still holds
        pool.free_seq(&mut donor);
        assert!(pool.slabs_in_use() >= held - chains, "index holds shared slabs");
        assert_eq!(read_k(&pool, &adopter, 0, adopter.len(), 2)[0], 2.0);

        // appending past the adopted prefix never perturbs the shared
        // slabs (copy-on-extend privatizes on write)
        let probe = read_k(&pool, &adopter, 0, adopter.len(), 0);
        let row = vec![7.0f32; dh];
        for c in 0..chains {
            pool.append(&mut adopter, c, adopter.len(), &row, &row);
        }
        adopter.advance();
        assert_eq!(read_k(&pool, &adopter, 0, adopter.len(), 0), probe);

        // a diverging prompt matches only up to the divergence point
        let mut fork = prompt.clone();
        fork[SLAB_TOKENS + 2] = -1;
        fork.push(904);
        let (_, m2) = index.lookup(&fork).unwrap();
        assert_eq!(m2, SLAB_TOKENS + 2);
        // match is capped at prompt_len − 1 (the last position is always
        // computed so admission has logits to sample from)
        let (_, m3) = index.lookup(&prompt).unwrap();
        assert_eq!(m3, prompt.len() - 1);
        assert!(index.lookup(&[999]).is_none());

        // clearing the index releases its holds
        index.clear(&mut pool);
        pool.free_seq(&mut adopter);
        assert_eq!(pool.slabs_in_use(), 0, "all holds released");
    }

    #[test]
    fn prefix_index_capacity_evicts_fifo_and_releases_refs() {
        let cfg = cfg();
        let mut pool = KvPool::new(&cfg);
        let mut index = PrefixIndex::new(2);
        let dh = cfg.head_dim;
        let row = vec![0.5f32; dh];
        let mut prompts = Vec::new();
        for p in 0..3i32 {
            let prompt: Vec<i32> = (0..6).map(|t| p * 100 + t).collect();
            let mut seq = pool.new_seq();
            for slot in 0..prompt.len() {
                for c in 0..cfg.depth * cfg.n_heads() {
                    pool.append(&mut seq, c, slot, &row, &row);
                }
            }
            pool.commit_prefill(&mut seq, prompt.len());
            index.insert(&prompt, &mut pool, &seq);
            pool.free_seq(&mut seq);
            prompts.push(prompt);
        }
        assert_eq!(index.len(), 2);
        // the oldest prompt was evicted FIFO; its slabs are free again
        assert!(index.lookup(&prompts[0]).is_none());
        assert!(index.lookup(&prompts[2]).is_some());
        index.clear(&mut pool);
        assert_eq!(pool.slabs_in_use(), 0);
        // duplicate insert is a no-op
        let mut seq = pool.new_seq();
        for slot in 0..4 {
            for c in 0..cfg.depth * cfg.n_heads() {
                pool.append(&mut seq, c, slot, &row, &row);
            }
        }
        pool.commit_prefill(&mut seq, 4);
        index.insert(&[1, 2, 3, 4], &mut pool, &seq);
        index.insert(&[1, 2, 3, 4], &mut pool, &seq);
        assert_eq!(index.len(), 1);
    }

    #[test]
    fn byte_accounting_matches_config_closed_forms() {
        let cfg = ModelConfig { width: 384, depth: 6, head_dim: 64, ..ModelConfig::default() };
        for ctx in [1usize, 17, 256] {
            assert_eq!(kv_bytes_read_per_token(&cfg, ctx), cfg.kv_cache_bytes_read_per_token(ctx));
            assert_eq!(
                kv_bytes_read_per_token_at(&cfg, ctx, 1) * 2,
                kv_bytes_read_per_token_at(&cfg, ctx, 2),
                "FP8 halves the read bytes"
            );
        }
        assert_eq!(kv_bytes_written_per_token(&cfg), cfg.kv_cache_bytes_per_token());
        for bpv in [1usize, 2] {
            assert_eq!(
                kv_bytes_written_per_token_at(&cfg, bpv),
                cfg.kv_cache_bytes_per_token_at(bpv)
            );
        }
        let pool = KvPool::new(&cfg);
        assert_eq!(pool.slab_bytes(), 2 * SLAB_TOKENS * cfg.head_dim * KV_BYTES_PER_VALUE);
    }
}
