//! Host tensor: the backend-agnostic exchange format at the L3<->runtime
//! boundary (replaces `xla::Literal` in the public API).
//!
//! The artifact ABI is f32 / i32 only by design — FP8/BF16 numerics live
//! *inside* the graphs (or inside the reference interpreter); master state
//! crosses the boundary in f32.

use super::manifest::Dtype;
use crate::util::error::{Context, Result};
use crate::{bail, err};

/// A host tensor's payload (the ABI is f32/i32 only by design).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    /// f32 payload.
    F32(Vec<f32>),
    /// i32 payload (token ids, seeds).
    I32(Vec<i32>),
}

/// Shaped host tensor — the exchange format at the L3<->runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: TensorData,
}

impl Tensor {
    /// f32 tensor from owned data (shape must match the element count).
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("tensor_f32: {} elements for shape {:?}", data.len(), shape);
        }
        Ok(Tensor { shape: shape.to_vec(), data: TensorData::F32(data) })
    }

    /// i32 tensor from owned data (shape must match the element count).
    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("tensor_i32: {} elements for shape {:?}", data.len(), shape);
        }
        Ok(Tensor { shape: shape.to_vec(), data: TensorData::I32(data) })
    }

    /// Scalar (rank-0) f32 tensor.
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    /// Scalar (rank-0) i32 tensor.
    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor { shape: vec![], data: TensorData::I32(vec![v]) }
    }

    /// All-zero f32 tensor of the given shape.
    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: TensorData::F32(vec![0.0; n]) }
    }

    /// The tensor's dimensions (empty = scalar).
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Element dtype of the payload.
    pub fn dtype(&self) -> Dtype {
        match self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }

    /// Element count of the payload.
    pub fn elements(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    /// Host-memory footprint of the payload (both dtypes are 4 bytes/elem).
    pub fn byte_len(&self) -> usize {
        self.elements() * 4
    }

    /// Borrow the f32 payload (error on i32 tensors).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(err!("tensor is i32, expected f32")),
        }
    }

    /// Borrow the i32 payload (error on f32 tensors).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(err!("tensor is f32, expected i32")),
        }
    }

    /// Copy the f32 payload out.
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_f32().map(|s| s.to_vec())
    }

    /// Scalar f32 accessor (shape [] or single-element tensors).
    pub fn scalar(&self) -> Result<f32> {
        let v = self.as_f32().context("reading scalar")?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }

    /// Scalar i32 accessor (shape [] or single-element tensors).
    pub fn scalar_i32_value(&self) -> Result<i32> {
        let v = self.as_i32().context("reading i32 scalar")?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }

    /// Overwrite an i32 tensor's payload in place (shape unchanged).
    /// Lets steady-state callers (the `Session` step path) reuse one host
    /// buffer instead of reallocating a tensor per step.
    pub fn copy_i32_from(&mut self, src: &[i32]) -> Result<()> {
        match &mut self.data {
            TensorData::I32(v) => {
                if v.len() != src.len() {
                    bail!("copy_i32_from: {} elements into tensor of {}", src.len(), v.len());
                }
                v.copy_from_slice(src);
                Ok(())
            }
            TensorData::F32(_) => Err(err!("tensor is f32, expected i32")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        assert!(Tensor::f32(vec![1.0, 2.0], &[3]).is_err());
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(t.elements(), 4);
        assert_eq!(t.byte_len(), 16);
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(t.shape(), &[2, 2]);
    }

    #[test]
    fn scalars() {
        assert_eq!(Tensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
        assert_eq!(Tensor::scalar_i32(-3).scalar_i32_value().unwrap(), -3);
        assert!(Tensor::scalar_i32(1).scalar().is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::i32(vec![1, 2], &[2]).unwrap();
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
    }

    #[test]
    fn copy_i32_from_reuses_buffer_and_checks_shape() {
        let mut t = Tensor::i32(vec![0, 0, 0], &[3]).unwrap();
        t.copy_i32_from(&[4, 5, 6]).unwrap();
        assert_eq!(t.as_i32().unwrap(), &[4, 5, 6]);
        assert!(t.copy_i32_from(&[1, 2]).is_err());
        let mut f = Tensor::scalar_f32(1.0);
        assert!(f.copy_i32_from(&[1]).is_err());
    }
}
