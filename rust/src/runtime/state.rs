//! `StatePrecision`: the low-precision optimizer/master-state policy.
//!
//! The paper's unit-variance discipline keeps every optimizer quantity
//! centered in the FP8 band, which is what makes low-precision *state*
//! safe (FP8-LM's recipe: FP8-ish moments + 16-bit masters + per-tensor
//! scales). This module is the policy's single source of truth:
//!
//!  - **Lion momentum → E4M3 + one per-tensor power-of-two scale.** Lion
//!    consumes only `sign(β1·m + (1-β1)·g)`, so momentum tolerates the
//!    ~6% E4M3 relative error; the scale exponent `k` is chosen per
//!    tensor as the *smallest* `k` with `amax ≤ 448·2^k`
//!    ([`momentum_scale_exp`]), so the cast **never saturates by
//!    construction** — `CastHealth.saturated == 0` is asserted in tests
//!    and CI, not hoped for.
//!  - **Master weights → BF16** (quantize-on-write, no f32 shadow): the
//!    Lion update `p - lr·sign(c) - wd·p` is computed in f32 from the
//!    BF16 grid values and rounded back to the grid once per step.
//!  - **f32 stays the default lane** ([`StatePrecision::F32`]), running
//!    the exact pre-policy code path — the bit-compat anchor.
//!
//! Representation: quantized state is held **on-grid in f32 storage**.
//! A momentum tensor's values all lie on the E4M3×2^k value grid, a
//! master tensor's on the BF16 grid. Because every grid value is exactly
//! f32-representable (for `k ≥ -126`, see [`pow2`]), the codecs here can
//! re-derive `k` from the data's own amax at encode time and round-trip
//! **bit-exactly** — no scale plumbing through the session/ABI, and
//! quantize→encode→decode is idempotent (the satellite test belt proves
//! this over the exhaustive E4M3 grid and randomized proptests).
//!
//! Byte accounting (the `ExecStats` gauges, `perfmodel` closed forms,
//! checkpoint v2 and the native momentum wire all agree on these):
//! E4M3 momentum is 1 B/elem, BF16 masters 2 B/elem → **3 B per
//! parameter element** of total state vs 8 today. The per-tensor scale
//! exponent is O(n_tensors) metadata (4 B/tensor); it is excluded from
//! the per-element gauges and counted explicitly where it becomes real
//! bytes (checkpoint payloads, wire payloads). See docs/NUMERICS.md §10.

use crate::fp8::{BF16, E4M3};
use crate::runtime::gemm;

/// Storage policy for the session's optimizer + master state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StatePrecision {
    /// f32 masters + f32 Lion momentum (8 B/param element). The default:
    /// bit-identical to the pre-policy trainer.
    #[default]
    F32,
    /// BF16 masters + E4M3 momentum with one power-of-two scale per
    /// tensor (3 B/param element). Quantize-on-write inside the fused
    /// train step; checkpoints and the DDP momentum wire ship the
    /// quantized payloads natively.
    Fp8,
}

impl StatePrecision {
    /// Parse a CLI name: `f32` (alias `master`) or `fp8`.
    pub fn by_name(name: &str) -> Option<StatePrecision> {
        match name {
            "f32" | "master" => Some(StatePrecision::F32),
            "fp8" => Some(StatePrecision::Fp8),
            _ => None,
        }
    }

    /// Stable label for reports/benches.
    pub fn label(self) -> &'static str {
        match self {
            StatePrecision::F32 => "f32",
            StatePrecision::Fp8 => "fp8",
        }
    }

    /// Bytes per master-weight element under this policy (4 or 2).
    pub fn master_bytes_per_elem(self) -> u64 {
        match self {
            StatePrecision::F32 => 4,
            StatePrecision::Fp8 => 2,
        }
    }

    /// Bytes per Lion-momentum element under this policy (4 or 1).
    pub fn momentum_bytes_per_elem(self) -> u64 {
        match self {
            StatePrecision::F32 => 4,
            StatePrecision::Fp8 => 1,
        }
    }

    /// Total state bytes per parameter element: master + momentum
    /// (8 for f32, 3 for fp8). Per-tensor scale exponents are O(n_tensors)
    /// metadata and excluded here (see the module docs).
    pub fn bytes_per_param_elem(self) -> u64 {
        self.master_bytes_per_elem() + self.momentum_bytes_per_elem()
    }
}

/// Exact `2^k` as f32 for `k ∈ [-126, 127]` (normal range only — the
/// momentum scale is clamped into it so every grid value and both scale
/// directions stay exactly representable).
#[inline]
pub fn pow2(k: i32) -> f32 {
    debug_assert!((-126..=127).contains(&k), "pow2 exponent {k} outside normal f32 range");
    f32::from_bits(((k + 127) as u32) << 23)
}

/// Smallest `k` with `amax ≤ 448·2^k` (448 = E4M3 max finite), clamped
/// to `[-126, 120]`; `0` for zero/non-finite amax. Computed from the f32
/// bit pattern: with `amax = m·2^e`, `1 ≤ m < 2`, the answer is `e - 8`
/// when `m ≤ 1.75` and `e - 7` otherwise (mantissa field `0x60_0000`
/// is exactly `m = 1.75`). Subnormal amax is pre-scaled by an exact
/// `2^64` so its exponent field is usable. Minimality gives the policy's
/// no-saturation guarantee; the lower clamp keeps every grid value
/// `c·2^k` (`|c| ≥ 2^-9`) exactly f32-representable.
pub fn momentum_scale_exp(amax: f32) -> i32 {
    if !amax.is_finite() || amax <= 0.0 {
        return 0;
    }
    let mut bits = amax.to_bits();
    let mut bias_adj = 0i32;
    if bits & 0x7F80_0000 == 0 {
        // f32-subnormal amax: multiply by 2^64 (exact: the product is
        // normal) and correct the exponent below.
        bits = (amax * f32::from_bits(0x5F80_0000)).to_bits();
        bias_adj = 64;
    }
    let e = ((bits >> 23) & 0xFF) as i32 - 127 - bias_adj;
    let k = if (bits & 0x7F_FFFF) <= 0x60_0000 { e - 8 } else { e - 7 };
    k.clamp(-126, 120)
}

/// Per-tensor momentum scale exponent: [`momentum_scale_exp`] of the
/// tensor's (deterministically reduced) absolute maximum.
pub fn momentum_scale(xs: &[f32]) -> i32 {
    momentum_scale_exp(gemm::abs_max(xs))
}

/// Quantize a momentum tensor onto its E4M3×2^k grid in place and return
/// `k`. RNE, sign- and signed-zero-preserving, and saturation-free by
/// the scale choice. Idempotent: on-grid input (any prior `k`) comes
/// back bit-identical — the re-derived exponent `k' ≤ k` and the E4M3
/// grid is closed under the exact `×2^(k-k')` refinement. Element-wise
/// with no accumulation, so the result is thread-count invariant.
pub fn snap_momentum(xs: &mut [f32]) -> i32 {
    let k = momentum_scale(xs);
    let fc = E4M3.fast_caster();
    let (scale, inv) = (pow2(k), pow2(-k));
    for x in xs.iter_mut() {
        *x = fc.cast(*x * inv) * scale;
    }
    k
}

/// Quantize a master-weight tensor onto the BF16 grid in place (RNE,
/// signed-zero-preserving; µS-scale weights sit far from the BF16 range
/// limit, so the raw cast cannot overflow).
pub fn snap_master(xs: &mut [f32]) {
    BF16.fast_caster().cast_slice(xs);
}

/// Encode a momentum tensor as `(scale_exp, one E4M3 byte per element)`.
/// The exponent is re-derived from the data, so on-grid input (what the
/// session stores under [`StatePrecision::Fp8`]) round-trips bit-exactly
/// through [`decode_momentum`]; off-grid input is quantized by the
/// encoding (same values [`snap_momentum`] would produce).
pub fn encode_momentum(xs: &[f32]) -> (i32, Vec<u8>) {
    let k = momentum_scale(xs);
    let inv = pow2(-k);
    let bytes = xs.iter().map(|&x| (E4M3.encode(x * inv) & 0xFF) as u8).collect();
    (k, bytes)
}

/// Decode an E4M3+scale momentum payload back to f32 grid values.
/// `scale_exp` must lie in `[-126, 120]` (callers validate file input).
pub fn decode_momentum(scale_exp: i32, bytes: &[u8]) -> Vec<f32> {
    debug_assert!(
        (-126..=120).contains(&scale_exp),
        "momentum scale exponent {scale_exp} out of range"
    );
    let lut = E4M3.decode_lut8();
    let scale = pow2(scale_exp);
    bytes.iter().map(|&b| lut[b as usize] * scale).collect()
}

/// Encode one master-weight value as BF16 bits (the high 16 bits of the
/// RNE-rounded f32). Exact for on-grid values.
#[inline]
pub fn encode_master(x: f32) -> u16 {
    (BF16.fast_caster().cast(x).to_bits() >> 16) as u16
}

/// Decode BF16 bits back to the f32 grid value.
#[inline]
pub fn decode_master(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::parallel;

    #[test]
    fn policy_names_labels_and_byte_constants() {
        assert_eq!(StatePrecision::by_name("f32"), Some(StatePrecision::F32));
        assert_eq!(StatePrecision::by_name("master"), Some(StatePrecision::F32));
        assert_eq!(StatePrecision::by_name("fp8"), Some(StatePrecision::Fp8));
        assert_eq!(StatePrecision::by_name("e4m3"), None);
        assert_eq!(StatePrecision::default(), StatePrecision::F32);
        assert_eq!(StatePrecision::F32.label(), "f32");
        assert_eq!(StatePrecision::Fp8.label(), "fp8");
        assert_eq!(StatePrecision::F32.bytes_per_param_elem(), 8);
        assert_eq!(StatePrecision::Fp8.bytes_per_param_elem(), 3);
        assert_eq!(StatePrecision::Fp8.master_bytes_per_elem(), 2);
        assert_eq!(StatePrecision::Fp8.momentum_bytes_per_elem(), 1);
    }

    #[test]
    fn pow2_matches_exp2_over_the_normal_range() {
        for k in -126..=127 {
            assert_eq!(pow2(k), (k as f64).exp2() as f32, "k={k}");
        }
    }

    #[test]
    fn scale_exp_is_minimal_at_boundaries() {
        // (amax, expected smallest k with amax <= 448·2^k)
        let cases: [f32; 10] = [
            448.0,
            448.0 * 2.0,
            449.0,
            1.75,      // exactly 448·2^-8
            1.7500001, // just above the boundary
            1.0,
            0.875, // exactly 448·2^-9
            f32::MAX,
            f32::MIN_POSITIVE, // clamps at k = -126
            1e30,
        ];
        for amax in cases {
            let k = momentum_scale_exp(amax);
            assert!((-126..=120).contains(&k));
            // defining property: amax fits at k…
            assert!(amax as f64 <= 448.0 * (k as f64).exp2(), "amax={amax} k={k}");
            // …and (unless clamped) not at k-1
            if k > -126 {
                assert!(
                    amax as f64 > 448.0 * ((k - 1) as f64).exp2(),
                    "k={k} not minimal for amax={amax}"
                );
            }
        }
        // exact table for the hand-checkable ones
        assert_eq!(momentum_scale_exp(448.0), 0);
        assert_eq!(momentum_scale_exp(449.0), 1);
        assert_eq!(momentum_scale_exp(1.75), -8);
        assert_eq!(momentum_scale_exp(1.0), -8);
        assert_eq!(momentum_scale_exp(0.875), -9);
    }

    #[test]
    fn scale_exp_degenerate_inputs() {
        assert_eq!(momentum_scale_exp(0.0), 0);
        assert_eq!(momentum_scale_exp(-1.0), 0);
        assert_eq!(momentum_scale_exp(f32::NAN), 0);
        assert_eq!(momentum_scale_exp(f32::INFINITY), 0);
        // f32 subnormals clamp at the bottom of the range
        assert_eq!(momentum_scale_exp(f32::from_bits(1)), -126); // 2^-149
        assert_eq!(momentum_scale_exp(f32::MIN_POSITIVE / 2.0), -126);
    }

    /// The tentpole guarantee: quantize→dequantize is the identity on the
    /// grid. Exhaustive over every E4M3 byte pattern × a spread of scale
    /// exponents, through both the in-place snap and the byte codec.
    #[test]
    fn exhaustive_e4m3_grid_roundtrips_bit_exact() {
        // k = 119 is the largest exponent whose whole grid (up to
        // 448·2^k = 1.75·2^127) stays f32-finite; larger k values are
        // only ever derived from amax near f32::MAX, where the produced
        // grid points stay at or below the data.
        let lut = E4M3.decode_lut8();
        for k in [-126i32, -40, -9, 0, 7, 63, 119] {
            let scale = pow2(k);
            for b in 0u16..=255 {
                let c = lut[b as usize];
                if c.is_nan() {
                    continue; // 0x7F / 0xFF are the e4m3fn NaN patterns
                }
                let v = c * scale;
                // in-place snap: bit-identical (covers signed zero at b=0x80)
                let mut xs = [v];
                snap_momentum(&mut xs);
                assert_eq!(
                    xs[0].to_bits(),
                    v.to_bits(),
                    "snap moved grid value {v} (byte {b:#04x}, k={k})"
                );
                // byte codec: bit-identical, sign bit included
                let (ke, bytes) = encode_momentum(&[v]);
                let back = decode_momentum(ke, &bytes);
                assert_eq!(
                    back[0].to_bits(),
                    v.to_bits(),
                    "codec moved grid value {v} (byte {b:#04x}, k={k} -> ke={ke})"
                );
            }
        }
    }

    #[test]
    fn snap_preserves_sign_and_never_saturates() {
        crate::util::proptest::check("snap_sign_saturation", 200, |rng, case| {
            // amax magnitude sweeps ~60 orders of magnitude across cases
            let std = 10f32.powi((case as i32 % 61) - 30);
            let mut xs = vec![0f32; 97];
            rng.fill_normal(&mut xs, std);
            xs[0] = 0.0;
            xs[1] = -0.0;
            let orig = xs.clone();
            let k = snap_momentum(&mut xs);
            let h = E4M3.cast_health(&orig, pow2(-k));
            prop_assert!(h.saturated == 0, "saturated {} at k={k}", h.saturated);
            for (o, q) in orig.iter().zip(&xs) {
                prop_assert!(
                    o.is_sign_negative() == q.is_sign_negative(),
                    "sign flipped: {o} -> {q}"
                );
                prop_assert!(
                    q.abs() <= 448.0 * pow2(k),
                    "off-band value {q} at k={k}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn snap_and_codec_are_idempotent_on_random_tensors() {
        crate::util::proptest::check("snap_idempotent", 120, |rng, case| {
            let mut xs = vec![0f32; 64];
            rng.fill_normal(&mut xs, 10f32.powi((case as i32 % 41) - 20));
            snap_momentum(&mut xs);
            let once: Vec<u32> = xs.iter().map(|x| x.to_bits()).collect();
            let k2 = snap_momentum(&mut xs);
            let twice: Vec<u32> = xs.iter().map(|x| x.to_bits()).collect();
            prop_assert!(once == twice, "second snap (k={k2}) changed bits");
            // codec round-trip of on-grid data is bit-exact
            let (ke, bytes) = encode_momentum(&xs);
            let back = decode_momentum(ke, &bytes);
            let back_bits: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
            prop_assert!(back_bits == once, "codec round-trip drifted (ke={ke})");
            Ok(())
        });
    }

    #[test]
    fn rne_ties_round_to_even_mantissa() {
        // Top binade (256..448, step 32): 432 is the midpoint of
        // 416 (mantissa 0b101) and 448 (0b110) -> even wins (448);
        // 400 is the midpoint of 384 (0b100) and 416 (0b101) -> 384.
        let mut xs = [448.0f32, 432.0, 400.0];
        let k = snap_momentum(&mut xs);
        assert_eq!(k, 0);
        assert_eq!(xs, [448.0, 448.0, 384.0]);
        // same ties under a shifted scale
        let mut ys = [448.0f32 * 0.25, 432.0 * 0.25, 400.0 * 0.25];
        let k = snap_momentum(&mut ys);
        assert_eq!(k, -2);
        assert_eq!(ys, [112.0, 112.0, 96.0]);
    }

    #[test]
    fn subnormal_band_roundtrips_at_the_scale_floor() {
        // Values whose grid sits below the f32 normal range: k clamps at
        // -126 and the E4M3-subnormal rungs m·2^-9·2^-126 are exact f32
        // subnormals.
        let rung = pow2(-126) / 512.0; // 2^-135
        let mut xs = [rung, 3.0 * rung, -7.0 * rung, 0.0];
        let orig = xs;
        let k = snap_momentum(&mut xs);
        assert_eq!(k, -126);
        for (o, q) in orig.iter().zip(&xs) {
            assert_eq!(o.to_bits(), q.to_bits(), "subnormal rung moved: {o} -> {q}");
        }
        let (ke, bytes) = encode_momentum(&xs);
        assert_eq!(ke, -126);
        let back = decode_momentum(ke, &bytes);
        for (o, b) in xs.iter().zip(&back) {
            assert_eq!(o.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn codec_is_bit_identical_across_thread_counts() {
        // The only reduction in the codec is the deterministic abs_max
        // fold; everything else is element-wise. Still: prove it.
        let mut rng = crate::util::rng::Rng::new(11);
        let mut base = vec![0f32; 10_000];
        rng.fill_normal(&mut base, 0.02f32);
        let runs: Vec<(i32, Vec<u8>, Vec<u32>)> = [1usize, 2, 4]
            .iter()
            .map(|&t| {
                parallel::with_max_threads(t, || {
                    let mut xs = base.clone();
                    let k = snap_momentum(&mut xs);
                    let (ke, bytes) = encode_momentum(&xs);
                    assert_eq!(k, ke);
                    (k, bytes, xs.iter().map(|x| x.to_bits()).collect())
                })
            })
            .collect();
        assert_eq!(runs[0], runs[1], "2-thread codec differs from 1-thread");
        assert_eq!(runs[0], runs[2], "4-thread codec differs from 1-thread");
    }

    #[test]
    fn master_codec_roundtrips_the_bf16_grid() {
        // every BF16 value is a u16 bit pattern; snap + codec must agree
        let mut pats: Vec<u16> = (0u16..=0xFFFF).collect();
        // exclude NaN/inf exponent patterns: exp field all-ones
        pats.retain(|&p| ((p >> 7) & 0xFF) != 0xFF);
        for &p in &pats {
            let v = decode_master(p);
            let mut xs = [v];
            snap_master(&mut xs);
            assert_eq!(xs[0].to_bits(), v.to_bits(), "snap moved bf16 value {v}");
            assert_eq!(encode_master(v), p, "encode changed bits for {v}");
        }
        // off-grid values round (RNE) onto the grid, then stay put
        let mut xs = [1.00390625f32]; // 1 + 2^-8: midpoint of 1.0 and 1+2^-7
        snap_master(&mut xs);
        assert_eq!(xs[0], 1.0); // ties to even mantissa
        assert_eq!(decode_master(encode_master(xs[0])).to_bits(), xs[0].to_bits());
    }
}
