//! Session: device-resident training state over a [`Backend`].
//!
//! Owns the `2 * n_params` state handles between steps, so the only
//! per-step host transfers are the token batch going in and the two
//! scalars (loss, grad-norm) coming out — full-state transfers happen at
//! explicit checkpoint/probe boundaries ([`Session::read_back`]) instead
//! of every step like the old `Engine` path. [`Session::stats`] accounts
//! those step-path transfers (time and bytes), which is what the bench
//! suite records to `BENCH_step.json`.

use std::time::Instant;

use super::backend::{Backend, ExecStats, TensorHandle};
use super::state::{self, StatePrecision};
use super::tensor::Tensor;
use crate::config::ModelConfig;
use crate::util::error::{Context, Error, Result};
use crate::{bail, err};

/// Host-side snapshot of the training state: `params ++ momenta`, all f32
/// master copies, in artifact input order.
#[derive(Debug, Clone)]
pub struct TrainState {
    /// `params ++ momenta`, in artifact input order.
    pub tensors: Vec<Tensor>,
    /// How many leading tensors are parameters (the rest are momenta).
    pub n_params: usize,
}

impl TrainState {
    /// The parameter tensors (momenta excluded).
    pub fn params(&self) -> &[Tensor] {
        &self.tensors[..self.n_params]
    }
}

/// One model's device-resident training state + the artifacts that act on
/// it. Sessions are single-threaded by design; parallel sweeps run one
/// session per worker thread over a shared (Sync) backend.
pub struct Session<'b> {
    backend: &'b dyn Backend,
    /// The model configuration this session trains.
    pub cfg: ModelConfig,
    train_name: String,
    init_name: String,
    n_params: usize,
    state: Vec<TensorHandle>,
    /// Reusable host-side token tensor (overwritten each step — no
    /// per-step allocation).
    tok_host: Tensor,
    /// Device handles for the lr/wd/tau scalars, cached by value: a scalar
    /// is re-uploaded only when its value changes (first step, or a
    /// schedule update), so constant hyperparameters cross the host
    /// boundary once, not every step.
    scalar_cache: [Option<(f32, TensorHandle)>; 3],
    /// Storage policy for the optimizer + master state. Under
    /// [`StatePrecision::Fp8`] the session resolves the
    /// `train_step_fp8state` artifact (quantize-on-write inside the fused
    /// update) and re-snaps incoming state onto the BF16/E4M3×2^k grids
    /// at the `init`/`load_state` boundaries, so the on-grid invariant
    /// holds even after off-grid host mutations (e.g. a DDP mean).
    precision: StatePrecision,
    stats: ExecStats,
}

impl<'b> Session<'b> {
    /// Resolve the train/init artifacts for `cfg` and validate the ABI.
    /// The session starts empty: call [`Session::init`] or
    /// [`Session::load_state`] before stepping. State is stored at
    /// [`StatePrecision::F32`] — bit-identical to the pre-policy trainer.
    pub fn new(backend: &'b dyn Backend, cfg: &ModelConfig) -> Result<Session<'b>> {
        Session::with_precision(backend, cfg, StatePrecision::F32)
    }

    /// [`Session::new`] under an explicit [`StatePrecision`] policy.
    /// `Fp8` resolves the `train_step_fp8state` artifact: Lion momentum
    /// kept on per-tensor E4M3×2^k grids, masters on the BF16 grid,
    /// 3 B/param element of state (vs 8) — reported by the
    /// [`Session::stats`] gauges.
    pub fn with_precision(
        backend: &'b dyn Backend,
        cfg: &ModelConfig,
        precision: StatePrecision,
    ) -> Result<Session<'b>> {
        let train_kind = match precision {
            StatePrecision::F32 => "train_step",
            StatePrecision::Fp8 => "train_step_fp8state",
        };
        let train = backend
            .resolve(train_kind, cfg)
            .with_context(|| format!("no {train_kind} artifact for config {}", cfg.name()))?;
        let init = backend
            .resolve("init", cfg)
            .with_context(|| format!("no init artifact for config {}", cfg.name()))?;
        let n_params = (train.inputs.len().saturating_sub(4)) / 2;
        if n_params == 0
            || train.inputs.len() != 2 * n_params + 4
            || train.outputs.len() != 2 * n_params + 2
        {
            bail!("unexpected train_step ABI for {}", cfg.name());
        }
        let tok_host = Tensor::i32(vec![0; cfg.batch * cfg.seq_len], &[cfg.batch, cfg.seq_len])?;
        Ok(Session {
            backend,
            cfg: cfg.clone(),
            train_name: train.name,
            init_name: init.name,
            n_params,
            state: Vec::new(),
            tok_host,
            scalar_cache: [None, None, None],
            precision,
            stats: ExecStats::default(),
        })
    }

    /// The state-storage policy this session runs under.
    pub fn state_precision(&self) -> StatePrecision {
        self.precision
    }

    /// The backend this session executes on.
    pub fn backend(&self) -> &'b dyn Backend {
        self.backend
    }

    /// Parameter-tensor count of the model (state = 2x this).
    pub fn n_params_tensors(&self) -> usize {
        self.n_params
    }

    /// Name of the resolved `train_step` artifact.
    pub fn train_artifact(&self) -> &str {
        &self.train_name
    }

    /// Step-path execution statistics: `calls` = steps taken,
    /// `transfer_*` covers ONLY what crosses the host boundary per step
    /// (tokens + hyperparameter scalars in, loss + gnorm out). Full-state
    /// reads via [`Session::read_back`] are deliberately not included —
    /// they are the checkpoint/probe boundary, not the step path.
    pub fn stats(&self) -> &ExecStats {
        &self.stats
    }

    fn drop_state(&mut self) {
        for h in self.state.drain(..) {
            self.backend.free(&h);
        }
    }

    /// Recompute the state-byte gauges from the live handles and the
    /// precision policy: masters at 4 (f32) or 2 (BF16) B/elem, momenta
    /// at 4 (f32) or 1 (E4M3) B/elem. Per-tensor scale exponents are
    /// O(n_tensors) metadata and excluded (they are counted where they
    /// become real bytes: checkpoint v2 payloads and the momentum wire).
    fn refresh_state_gauges(&mut self) {
        let elems = |hs: &[TensorHandle]| hs.iter().map(|h| h.elements() as u64).sum::<u64>();
        let param_elems = elems(&self.state[..self.n_params]);
        let mom_elems = elems(&self.state[self.n_params..]);
        self.stats.state_bytes = param_elems * self.precision.master_bytes_per_elem()
            + mom_elems * self.precision.momentum_bytes_per_elem();
        self.stats.state_bytes_per_param =
            self.stats.state_bytes as f64 / param_elems.max(1) as f64;
    }

    /// Initialize state on-device by running the `init` artifact
    /// (unit-variance / sigma_init inits happen in-graph). Under
    /// [`StatePrecision::Fp8`] the fresh state is then snapped onto the
    /// storage grids (one extra round trip at this boundary — never on
    /// the step path).
    pub fn init(&mut self, seed: i32) -> Result<()> {
        let seed_t = Tensor::scalar_i32(seed);
        let h = self.backend.upload(&seed_t)?;
        let outs = self.backend.execute(&self.init_name, std::slice::from_ref(&h));
        self.backend.free(&h);
        let outs = outs?;
        if outs.len() != 2 * self.n_params {
            for h in &outs {
                self.backend.free(h);
            }
            bail!("init produced {} tensors, expected {}", outs.len(), 2 * self.n_params);
        }
        self.drop_state();
        self.state = outs;
        if self.precision == StatePrecision::Fp8 {
            // quantize the f32 init onto the grids via the load path
            let snapshot = self.read_back()?;
            self.load_state(&snapshot)?;
        } else {
            self.refresh_state_gauges();
        }
        Ok(())
    }

    /// Upload a host snapshot as the new device-resident state. Under
    /// [`StatePrecision::Fp8`] each tensor is first snapped onto its
    /// storage grid (BF16 masters, E4M3×2^k momenta) — a bit-exact no-op
    /// for state that is already on-grid (an FP8-lane checkpoint), and
    /// the re-quantization point for off-grid host math (a DDP mean).
    pub fn load_state(&mut self, state: &TrainState) -> Result<()> {
        if state.tensors.len() != 2 * self.n_params {
            bail!(
                "state has {} tensors, session expects {}",
                state.tensors.len(),
                2 * self.n_params
            );
        }
        let mut handles = Vec::with_capacity(state.tensors.len());
        for (i, t) in state.tensors.iter().enumerate() {
            let h = match self.precision {
                StatePrecision::F32 => self.backend.upload(t)?,
                StatePrecision::Fp8 => {
                    let mut data = t.to_f32_vec()?;
                    if i < self.n_params {
                        state::snap_master(&mut data);
                    } else {
                        state::snap_momentum(&mut data);
                    }
                    self.backend.upload(&Tensor::f32(data, t.shape())?)?
                }
            };
            handles.push(h);
        }
        self.drop_state();
        self.state = handles;
        self.refresh_state_gauges();
        Ok(())
    }

    /// Transfer the full state back to the host (checkpoint / probe /
    /// allreduce boundary). The device copy stays resident.
    pub fn read_back(&self) -> Result<TrainState> {
        if self.state.is_empty() {
            bail!("session state not initialized (call init or load_state)");
        }
        let mut tensors = Vec::with_capacity(self.state.len());
        for h in &self.state {
            tensors.push(self.backend.download(h).context("reading back train state")?);
        }
        Ok(TrainState { tensors, n_params: self.n_params })
    }

    /// Host copies of the parameter tensors only (for eval / probes).
    pub fn params_host(&self) -> Result<Vec<Tensor>> {
        if self.state.is_empty() {
            bail!("session state not initialized (call init or load_state)");
        }
        let mut out = Vec::with_capacity(self.n_params);
        for h in &self.state[..self.n_params] {
            out.push(self.backend.download(h).context("reading back params")?);
        }
        Ok(out)
    }

    /// One optimizer step. `lr` is the base-width learning rate for this
    /// step (scheduling already applied); tokens length must be batch*seq.
    /// Only the token batch, any *changed* hyperparameter scalars (in),
    /// and the loss/gnorm scalars (out) cross the host boundary — constant
    /// scalars are uploaded once and their device handles reused, and the
    /// host token buffer is reused across steps. `transfer_bytes` counts
    /// only what actually moved.
    pub fn step(&mut self, tokens: &[i32], lr: f64, wd: f64, tau: f64) -> Result<(f32, f32)> {
        if self.state.is_empty() {
            bail!("session state not initialized (call init or load_state)");
        }
        let t0 = Instant::now();
        self.tok_host.copy_i32_from(tokens).context("packing token batch")?;
        let tok_bytes = self.tok_host.byte_len() as u64;
        let tok_h = self.backend.upload(&self.tok_host)?;
        let mut moved_bytes = tok_bytes;
        for (slot, v) in [lr as f32, wd as f32, tau as f32].into_iter().enumerate() {
            let cached = matches!(
                &self.scalar_cache[slot],
                Some((cv, _)) if cv.to_bits() == v.to_bits()
            );
            if !cached {
                let h = self.backend.upload(&Tensor::scalar_f32(v))?;
                if let Some((_, old)) = self.scalar_cache[slot].replace((v, h)) {
                    self.backend.free(&old);
                }
                moved_bytes += 4;
            }
        }
        let t1 = Instant::now();

        let mut inputs: Vec<TensorHandle> = Vec::with_capacity(self.state.len() + 4);
        inputs.extend(self.state.iter().cloned());
        inputs.push(tok_h.clone());
        for slot in &self.scalar_cache {
            let (_, h) =
                slot.as_ref().ok_or_else(|| err!("scalar cache slot empty after fill pass"))?;
            inputs.push(h.clone());
        }
        let result = self.backend.execute(&self.train_name, &inputs);
        self.backend.free(&tok_h);
        let mut outs = result?;
        let t2 = Instant::now();

        if outs.len() != 2 * self.n_params + 2 {
            for h in &outs {
                self.backend.free(h);
            }
            bail!(
                "train_step '{}' produced {} outputs, expected {}",
                self.train_name,
                outs.len(),
                2 * self.n_params + 2
            );
        }
        let gnorm_h = outs.pop().ok_or_else(|| err!("missing gnorm output"))?;
        let loss_h = outs.pop().ok_or_else(|| err!("missing loss output"))?;
        let loss_res = self
            .backend
            .download(&loss_h)
            .and_then(|t| t.scalar())
            .with_context(|| format!("reading loss scalar from '{}'", self.train_name));
        let gnorm_res = self
            .backend
            .download(&gnorm_h)
            .and_then(|t| t.scalar())
            .with_context(|| format!("reading gnorm scalar from '{}'", self.train_name));
        self.backend.free(&loss_h);
        self.backend.free(&gnorm_h);
        let (loss, gnorm) = match (loss_res, gnorm_res) {
            (Ok(l), Ok(g)) => (l, g),
            (l, g) => {
                // don't strand the new state generation in the store
                for h in &outs {
                    self.backend.free(h);
                }
                return Err(l
                    .err()
                    .or_else(|| g.err())
                    .unwrap_or_else(|| Error::msg("loss/gnorm readback failed without error")));
            }
        };
        let t3 = Instant::now();

        // adopt the new state; free the old generation
        self.drop_state();
        self.state = outs;

        self.stats.calls += 1;
        self.stats.execute_time += t2 - t1;
        self.stats.transfer_time += (t1 - t0) + (t3 - t2);
        self.stats.transfer_bytes += moved_bytes + 2 * 4;
        Ok((loss, gnorm))
    }

    /// [`Session::step`] under a [`crate::telemetry::capture`] scope:
    /// returns the step's `(loss, gnorm)` plus everything the interpreter
    /// recorded — per-op forward/backward RMS and FP8 cast-health
    /// counters. Recording is read-only, so a traced step produces a
    /// bit-identical `TrainState` to an untraced one (tested at trainer
    /// level for both FP8 lanes across 1/2/4 worker threads).
    ///
    /// The sink is thread-scoped and the reference backend interprets on
    /// the calling thread; backends that execute elsewhere return an
    /// empty report.
    pub fn step_traced(
        &mut self,
        tokens: &[i32],
        lr: f64,
        wd: f64,
        tau: f64,
    ) -> Result<(f32, f32, crate::telemetry::TelemetryReport)> {
        let (res, report) = crate::telemetry::capture(|| self.step(tokens, lr, wd, tau));
        let (loss, gnorm) = res?;
        Ok((loss, gnorm, report))
    }
}

impl Drop for Session<'_> {
    fn drop(&mut self) {
        self.drop_state();
        for slot in &mut self.scalar_cache {
            if let Some((_, h)) = slot.take() {
                self.backend.free(&h);
            }
        }
    }
}
