//! `InferSession`: the session-layer inference engine (KV-cache decode).
//!
//! The training [`super::Session`] owns device-resident *train* state;
//! this is its serving counterpart: parameters are quantized **once** at
//! construction (the upload boundary — the same static E4M3/E5M2 /
//! BF16 casts [`super::block::quantize_params`] applies every training
//! step), then any number of sequences run
//!
//!  - [`InferSession::prefill`] — the prompt pass. This IS the training
//!    forward: it calls `block::logits_rows`, the same tower the
//!    `fwd` artifact executes, with a per-layer KV sink that captures
//!    each block's BF16 post-RoPE K/V into the paged cache
//!    ([`super::kvcache`]). Prefill logits are bit-identical to the
//!    training forward's by construction.
//!  - [`InferSession::decode_step`] / [`InferSession::decode_batch`] —
//!    incremental decode: one token per live sequence through the same
//!    per-op pipeline (`op_embed` → per block { `op_rmsnorm` /
//!    `op_linear` / RoPE / single-query cached attention / `apply_act` /
//!    `residual_combine` } → `op_rmsnorm` → LM head), with attention
//!    served from the KV cache by `gemm::attn_decode_cached` — the same
//!    inner kernel (`attn_one_query`) the training forward runs per row,
//!    in the same accumulation order. Under the µS static-FP8 and BF16
//!    plans a decode step therefore reproduces the matching full-forward
//!    logits row bit for bit (tested); dynamic SP+FP8 computes per-tensor
//!    amaxes over whatever batch it sees, so its decode numerics depend
//!    on batch composition — the serving-side cost of dynamic scaling
//!    the paper's static recipe deletes.
//!
//! Decode batches all live sequences into one execute: every dense op
//! runs over `[rows, d]` with one row per sequence, and attention
//! parallelizes over (sequence, head) pairs with fixed chunk boundaries
//! — bit-deterministic at any worker-thread count, and row-local for
//! static plans, so a sequence's tokens do not depend on who it was
//! batched with (the continuous-batching invariant `coordinator::serve`
//! tests).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::block::{self, NormPlacement, Prepared, QuantMode, QuantParams};
use super::gemm::{attn_decode_cached, matmul_bt_quant, KvCodec};
use super::kvcache::{KvPool, KvStoreMode, PrefixIndex, SeqKv};
use super::tensor::Tensor;
use crate::config::ModelConfig;
use crate::fp8::{CastHealth, E4M3};
use crate::telemetry;
use crate::util::error::Result;
use crate::util::parallel;
use crate::util::rng::Rng;
use crate::{bail, err};

/// Handle to one live sequence in an [`InferSession`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SeqId(u64);

/// Cumulative inference-path statistics (the serving analog of
/// `ExecStats`): prefill and decode are accounted separately because
/// prefill is compute-bound and decode bandwidth-bound.
#[derive(Debug, Clone, Default)]
pub struct InferStats {
    /// Prefill executes (one per prompt).
    pub prefill_calls: usize,
    /// Prompt tokens pushed through prefill.
    pub prefill_tokens: u64,
    /// Wall time spent inside prefill executes.
    pub prefill_time: Duration,
    /// Batched decode executes (one per serve step, not per token).
    pub decode_steps: usize,
    /// Tokens decoded (one per live sequence per step).
    pub decode_tokens: u64,
    /// Wall time spent inside decode executes.
    pub decode_time: Duration,
    /// FLOPs executed by prefill passes (tower and chunked), enumerated
    /// at the op sites from the actual GEMM/attention loop dimensions.
    /// `perfmodel::prefill_flops` is the independently derived closed
    /// form; a test pins exact equality.
    pub prefill_flops: u64,
    /// FLOPs executed by decode steps (same enumeration contract;
    /// `perfmodel::decode_flops_per_token` is the closed form).
    pub decode_flops: u64,
    /// KV-cache bytes encoded into slabs, enumerated per appended row
    /// (`2 · head_dim · bytes_per_value` per (position, layer, head)).
    pub kv_bytes_written: u64,
    /// KV-cache bytes streamed by cached-attention gathers, enumerated
    /// per (row, head) pair at its actual context length.
    pub kv_bytes_read: u64,
    /// Bytes copied by prefix-adoption partial-tail copies (shared full
    /// slabs cost zero bytes — that is the point of the prefix cache).
    pub kv_bytes_copied: u64,
    /// Prompt tokens whose K/V came from the prefix cache instead of
    /// being recomputed (cumulative over [`InferSession::adopt_prefix`]).
    pub prefix_hit_tokens: u64,
    /// Prefix-cache lookups that matched at least one token.
    pub prefix_hits: u64,
}

/// Preallocated `[rows, ·]` buffers for batched decode, grown on demand
/// and reused across steps (the decode hot path allocates nothing but
/// the per-layer page lists).
struct DecodeWorkspace {
    rows_cap: usize,
    x: Vec<f32>,
    xq: Vec<f32>,
    xmid: Vec<f32>,
    t0: Vec<f32>,
    t1: Vec<f32>,
    n: Vec<f32>,
    r: Vec<f32>,
    z_qkv: Vec<f32>,
    q_heads: Vec<f32>,
    k_heads: Vec<f32>,
    v_heads: Vec<f32>,
    o_heads: Vec<f32>,
    z_up: Vec<f32>,
    xq_down: Vec<f32>,
    y: Vec<f32>,
    /// Per-(sequence, head) gather + score scratch:
    /// `[kf: cap·dh][vf: cap·dh][scores: cap]` per pair.
    attn_scratch: Vec<f32>,
    logits: Vec<f32>,
    toks: Vec<i32>,
    pos: Vec<usize>,
    /// Per-(sequence, head) `[start, end)` ranges into the per-layer
    /// flat page lists (reused across layers and steps).
    page_bounds: Vec<(usize, usize)>,
}

impl DecodeWorkspace {
    fn new() -> DecodeWorkspace {
        DecodeWorkspace {
            rows_cap: 0,
            x: Vec::new(),
            xq: Vec::new(),
            xmid: Vec::new(),
            t0: Vec::new(),
            t1: Vec::new(),
            n: Vec::new(),
            r: Vec::new(),
            z_qkv: Vec::new(),
            q_heads: Vec::new(),
            k_heads: Vec::new(),
            v_heads: Vec::new(),
            o_heads: Vec::new(),
            z_up: Vec::new(),
            xq_down: Vec::new(),
            y: Vec::new(),
            attn_scratch: Vec::new(),
            logits: Vec::new(),
            toks: Vec::new(),
            pos: Vec::new(),
            page_bounds: Vec::new(),
        }
    }

    fn ensure(&mut self, cfg: &ModelConfig, rows: usize, cap: usize) {
        if rows <= self.rows_cap {
            return;
        }
        let (d, f, v, h) = (cfg.width, cfg.ffn_width(), cfg.vocab, cfg.n_heads());
        let dh = cfg.head_dim;
        self.rows_cap = rows;
        self.x = vec![0f32; rows * d];
        self.xq = vec![0f32; rows * d];
        self.xmid = vec![0f32; rows * d];
        self.t0 = vec![0f32; rows * d];
        self.t1 = vec![0f32; rows * d];
        self.n = vec![0f32; rows * d];
        self.r = vec![0f32; rows];
        self.z_qkv = vec![0f32; rows * 3 * d];
        self.q_heads = vec![0f32; rows * d];
        self.k_heads = vec![0f32; rows * d];
        self.v_heads = vec![0f32; rows * d];
        self.o_heads = vec![0f32; rows * d];
        self.z_up = vec![0f32; rows * f];
        self.xq_down = vec![0f32; rows * f];
        self.y = vec![0f32; rows * d];
        self.attn_scratch = vec![0f32; rows * h * (2 * cap * dh + cap)];
        self.logits = vec![0f32; rows * v];
        self.toks = vec![0i32; rows];
        self.pos = vec![0usize; rows];
        self.page_bounds = Vec::with_capacity(rows * h);
    }
}

/// One model's inference state: quantized parameters + the KV-cache pool
/// + per-sequence cache chains. Single-threaded by design (the decode
/// execute is internally parallel); serving drives it from one loop.
pub struct InferSession {
    cfg: ModelConfig,
    prep: Prepared,
    params: Vec<Vec<f32>>,
    qp: QuantParams,
    pool: KvPool,
    seqs: HashMap<u64, SeqKv>,
    next_id: u64,
    dws: DecodeWorkspace,
    stats: InferStats,
    /// Prompt-prefix index (None until enabled by the serving layer).
    prefix: Option<PrefixIndex>,
    /// E4M3 byte → f32 table for the FP8 KV gather (`Format::decode_lut8`).
    e4m3_lut: [f32; 256],
}

/// Which accounting bucket a row-core execute belongs to.
#[derive(Clone, Copy, PartialEq, Eq)]
enum RowKind {
    Prefill,
    Decode,
}

impl InferSession {
    /// Build from host parameter tensors (state order, e.g.
    /// `Session::params_host()` / `TrainState::params()`). Quantizes the
    /// weights once with the config's per-op [`block::Plan`] — the same
    /// casts training applies — and resolves the per-call invariants
    /// ([`Prepared`]). Context capacity is `cfg.seq_len` (the RoPE-table
    /// range the model trained under).
    pub fn new(cfg: &ModelConfig, params: &[Tensor], tau: f32) -> Result<InferSession> {
        let specs = block::param_specs(cfg);
        if params.len() != specs.len() {
            bail!("expected {} parameter tensors, got {}", specs.len(), params.len());
        }
        let mut host = Vec::with_capacity(params.len());
        for (t, spec) in params.iter().zip(&specs) {
            if t.elements() != spec.elements() {
                bail!(
                    "param tensor {} has {} elements, expected {}",
                    spec.name,
                    t.elements(),
                    spec.elements()
                );
            }
            host.push(t.to_f32_vec()?);
        }
        InferSession::from_params(cfg, host, tau)
    }

    /// Build from raw parameter buffers (state order).
    pub(crate) fn from_params(
        cfg: &ModelConfig,
        params: Vec<Vec<f32>>,
        tau: f32,
    ) -> Result<InferSession> {
        let prep = Prepared::new(cfg, tau)?;
        let qp = block::quantize_params(cfg, &params, &prep.plan, false);
        Ok(InferSession {
            cfg: cfg.clone(),
            prep,
            params,
            qp,
            pool: KvPool::new(cfg),
            seqs: HashMap::new(),
            next_id: 0,
            dws: DecodeWorkspace::new(),
            stats: InferStats::default(),
            prefix: None,
            e4m3_lut: E4M3.decode_lut8(),
        })
    }

    /// The model configuration this session serves.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Maximum cached positions per sequence (the RoPE-table range).
    pub fn context_capacity(&self) -> usize {
        self.cfg.seq_len
    }

    /// Sequences currently registered (holding KV state).
    pub fn live_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// KV slabs currently held by live sequences (memory ∝ live tokens).
    pub fn kv_slabs_in_use(&self) -> usize {
        self.pool.slabs_in_use()
    }

    /// KV-cache bytes currently resident (slab payloads).
    pub fn kv_bytes_in_use(&self) -> usize {
        self.pool.slabs_in_use() * self.pool.slab_bytes()
    }

    /// KV-cache bytes resident (in-use AND free-but-materialized slab
    /// payloads — what [`InferSession::kv_trim`] can shrink).
    pub fn kv_materialized_bytes(&self) -> usize {
        self.pool.materialized_bytes()
    }

    /// Largest resident KV byte footprint the pool ever reached.
    pub fn kv_high_water_bytes(&self) -> usize {
        self.pool.high_water_bytes()
    }

    /// Release the backing memory of free KV slabs down to at most
    /// `target_slabs` materialized buffers (in-use slabs are never
    /// touched). The serving scheduler calls this between steps so one
    /// long-prompt burst no longer pins peak memory forever.
    pub fn kv_trim(&mut self, target_slabs: usize) {
        self.pool.trim(target_slabs);
    }

    /// The KV-cache storage codec in effect.
    pub fn kv_store_mode(&self) -> KvStoreMode {
        self.pool.mode()
    }

    /// Switch the KV-cache storage codec. Only legal with zero live
    /// sequences (cached bytes are not transcoded); drops any prefix-
    /// cache entries and resets the pool (including its high-water mark).
    pub fn set_kv_store_mode(&mut self, mode: KvStoreMode) -> Result<()> {
        if !self.seqs.is_empty() {
            bail!("cannot switch KV store mode with {} live sequences", self.seqs.len());
        }
        let Self { cfg, pool, prefix, .. } = self;
        if let Some(ix) = prefix.as_mut() {
            ix.clear(pool);
        }
        *pool = KvPool::new_with_mode(cfg, mode);
        Ok(())
    }

    /// Cumulative cast health of every FP8 KV append (empty under BF16) —
    /// under µS the `saturated` count stays 0, the per-slab static
    /// scale-1.0 proof (see `runtime::kvcache`).
    pub fn fp8_kv_health(&self) -> CastHealth {
        self.pool.fp8_health()
    }

    /// Live FP8 KV slabs whose per-slab health recorded any saturation.
    pub fn fp8_kv_saturated_slabs(&self) -> usize {
        self.pool.fp8_saturated_slabs()
    }

    /// Enable (or reset) the prompt-prefix cache with room for
    /// `capacity` cached prefixes (FIFO eviction).
    pub fn enable_prefix_cache(&mut self, capacity: usize) {
        let Self { pool, prefix, .. } = self;
        if let Some(ix) = prefix.as_mut() {
            ix.clear(pool);
        }
        *prefix = Some(PrefixIndex::new(capacity));
    }

    /// Cached prompt prefixes currently indexed.
    pub fn prefix_entries(&self) -> usize {
        self.prefix.as_ref().map(|ix| ix.len()).unwrap_or(0)
    }

    /// Seed the empty sequence `id` from the longest cached prefix of
    /// `tokens`: full slabs are shared by refcount (zero copy), a
    /// partial tail slab is copied privately. Returns the number of
    /// prompt positions now cached (0 when the cache is off or misses);
    /// the caller prefills only the remaining suffix. Matches are capped
    /// at `tokens.len() − 1`, so at least one position is always left
    /// for the caller to compute logits from.
    pub fn adopt_prefix(&mut self, id: SeqId, tokens: &[i32]) -> Result<usize> {
        let Self { pool, seqs, prefix, stats, .. } = self;
        let seq = seqs.get_mut(&id.0).ok_or_else(|| err!("unknown sequence {id:?}"))?;
        if seq.len() != 0 {
            bail!("prefix adoption into non-empty sequence {id:?}");
        }
        let Some(ix) = prefix.as_ref() else { return Ok(0) };
        let Some((entry, m)) = ix.lookup(tokens) else { return Ok(0) };
        stats.kv_bytes_copied += ix.adopt(entry, m, pool, seq);
        stats.prefix_hits += 1;
        stats.prefix_hit_tokens += m as u64;
        Ok(m)
    }

    /// Index the first `tokens.len()` cached positions of `id` (its
    /// prompt) in the prefix cache, taking refcount holds on the
    /// covering slabs. No-op when the cache is off or the chain is
    /// already indexed.
    pub fn insert_prefix(&mut self, id: SeqId, tokens: &[i32]) -> Result<()> {
        let Self { pool, seqs, prefix, .. } = self;
        let Some(ix) = prefix.as_mut() else { return Ok(()) };
        let seq = seqs.get(&id.0).ok_or_else(|| err!("unknown sequence {id:?}"))?;
        if seq.len() < tokens.len() {
            bail!("sequence {id:?} caches {} positions, prompt has {}", seq.len(), tokens.len());
        }
        ix.insert(tokens, pool, seq);
        Ok(())
    }

    /// Cumulative prefill/decode accounting.
    pub fn stats(&self) -> &InferStats {
        &self.stats
    }

    /// Register a fresh sequence (no cache pages held until prefill).
    pub fn add_sequence(&mut self) -> SeqId {
        let id = self.next_id;
        self.next_id += 1;
        self.seqs.insert(id, self.pool.new_seq());
        SeqId(id)
    }

    /// Cached positions of a live sequence.
    pub fn sequence_len(&self, id: SeqId) -> Result<usize> {
        self.seqs.get(&id.0).map(|s| s.len()).ok_or_else(|| err!("unknown sequence {id:?}"))
    }

    /// Evict a sequence, returning its cache pages to the pool.
    pub fn free_sequence(&mut self, id: SeqId) -> Result<()> {
        let mut seq =
            self.seqs.remove(&id.0).ok_or_else(|| err!("unknown sequence {id:?}"))?;
        self.pool.free_seq(&mut seq);
        Ok(())
    }

    /// Prompt pass: forward `tokens` through the training tower (batch 1,
    /// geometry `1 × len`), capturing every layer's K/V into the cache.
    /// Returns the logits `[len · vocab]` — bit-identical to the `fwd`
    /// artifact's rows for this sequence under static-FP8/BF16 plans.
    pub fn prefill(&mut self, id: SeqId, tokens: &[i32]) -> Result<Vec<f32>> {
        let Self { cfg, prep, params, qp, pool, seqs, stats, .. } = self;
        let seq = seqs.get_mut(&id.0).ok_or_else(|| err!("unknown sequence {id:?}"))?;
        if seq.len() != 0 {
            bail!("sequence {id:?} already holds {} cached positions", seq.len());
        }
        let s = tokens.len();
        if s == 0 || s > cfg.seq_len {
            bail!("prefill length {s} outside 1..={} (context capacity)", cfg.seq_len);
        }
        block::check_tokens(tokens, cfg.vocab)?;
        let (h, dh) = (cfg.n_heads(), cfg.head_dim);
        let bpv = pool.bytes_per_value();
        let h0 = pool.fp8_health();
        let t0 = Instant::now();
        let mut kv_written = 0u64;
        let mut sink = |l: usize, qkv_heads: &[f32]| {
            // batch = 1: chunk hh of qkv_heads is [q(s,dh), k(s,dh), v(s,dh)]
            for hh in 0..h {
                let base = hh * 3 * s * dh;
                let chain = pool.chain_of(h, l, hh);
                for t in 0..s {
                    let k = &qkv_heads[base + s * dh + t * dh..base + s * dh + (t + 1) * dh];
                    let v = &qkv_heads
                        [base + 2 * s * dh + t * dh..base + 2 * s * dh + (t + 1) * dh];
                    pool.append(seq, chain, t, k, v);
                    kv_written += (2 * dh * bpv) as u64;
                }
            }
        };
        let logits = block::logits_rows(cfg, prep, qp, params, tokens, 1, s, Some(&mut sink));
        pool.commit_prefill(seq, s);
        // op-level FLOP enumeration of the pass the tower just ran: the
        // four hidden GEMMs per token per layer, causal attention row t
        // scoring+mixing t+1 keys over all heads (4·d·(t+1)), the LM head
        if pool.mode() == KvStoreMode::Fp8E4m3 && telemetry::enabled() {
            telemetry::record_cast("kv_cache", 0, "e4m3", health_delta(pool.fp8_health(), h0));
        }
        let hidden_per_tok: u64 =
            block::hidden_gemm_shapes(cfg).iter().map(|&(_, o, i)| 2 * (o * i) as u64).sum();
        for _l in 0..cfg.depth {
            for t in 0..s {
                stats.prefill_flops += hidden_per_tok + 4 * cfg.width as u64 * (t as u64 + 1);
            }
        }
        stats.prefill_flops += s as u64 * 2 * (cfg.width * cfg.vocab) as u64;
        stats.kv_bytes_written += kv_written;
        stats.prefill_calls += 1;
        stats.prefill_tokens += s as u64;
        stats.prefill_time += t0.elapsed();
        Ok(logits)
    }

    /// Single-sequence decode convenience over [`InferSession::decode_batch`].
    pub fn decode_step(&mut self, id: SeqId, token: i32) -> Result<Vec<f32>> {
        let mut out = self.decode_batch(&[(id, token)])?;
        out.pop().ok_or_else(|| err!("decode batch for {id:?} returned no logits row"))
    }

    /// One incremental decode step for a batch of live sequences: feed
    /// each `(sequence, token)` pair, append its K/V, and return each
    /// sequence's next-token logits (`[vocab]` per item, in input order).
    /// All items run as ONE execute — one `[rows, d]` pass through the
    /// shared op pipeline per layer, attention parallel over
    /// (sequence, head) pairs.
    pub fn decode_batch(&mut self, items: &[(SeqId, i32)]) -> Result<Vec<Vec<f32>>> {
        for (i, (id, _)) in items.iter().enumerate() {
            if items[..i].iter().any(|(other, _)| other == id) {
                bail!("sequence {id:?} appears twice in one decode batch");
            }
        }
        let rows: Vec<(u64, i32)> = items.iter().map(|(id, tok)| (id.0, *tok)).collect();
        let v = self.cfg.vocab;
        let flat = self.run_rows(&rows, RowKind::Decode)?;
        Ok((0..items.len()).map(|r| flat[r * v..(r + 1) * v].to_vec()).collect())
    }

    /// Chunked prefill: push the next `tokens.len()` prompt positions of
    /// sequence `id` through the decode row core as one execute —
    /// `tokens[i]` lands at position `len + i`, and its attention row
    /// sees exactly the `len + i + 1` cached entries a causal forward
    /// would (every row's K/V is appended before any row attends).
    /// Under the µS static-FP8/BF16 plans the logits are therefore
    /// bit-identical to a whole-prompt [`InferSession::prefill`] at ANY
    /// chunk size (tested for {1, SLAB_TOKENS−1, SLAB_TOKENS,
    /// prompt_len}); it also continues seamlessly after
    /// [`InferSession::adopt_prefix`] seeds the prefix. Returns the
    /// chunk's logits rows (`[tokens.len() · vocab]`). The serving
    /// scheduler interleaves these chunks with decode steps so a long
    /// admission no longer stalls every live decode.
    pub fn prefill_chunk(&mut self, id: SeqId, tokens: &[i32]) -> Result<Vec<f32>> {
        if tokens.is_empty() {
            bail!("empty prefill chunk for sequence {id:?}");
        }
        let rows: Vec<(u64, i32)> = tokens.iter().map(|&t| (id.0, t)).collect();
        self.run_rows(&rows, RowKind::Prefill)
    }

    /// The row core shared by [`InferSession::decode_batch`] (one row
    /// per live sequence) and [`InferSession::prefill_chunk`] (many rows
    /// of one sequence at consecutive positions): appends every row's
    /// K/V, runs the per-op pipeline over `[rows, d]`, and returns the
    /// flat logits `[rows · vocab]`.
    ///
    /// The per-layer loop below mirrors `forward_tower`'s schedule (same
    /// ops, same order, same quantize points — only the buffering and the
    /// cached attention differ). The mirror is pinned by the
    /// decode-vs-fwd bit-identity tests: any sequencing edit to either
    /// side that changes numerics fails them for the static-FP8/BF16
    /// plans (SP+FP8's dynamic amax is batch-shape-dependent by design,
    /// so its decode has no bit-match to pin — see the module docs).
    fn run_rows(&mut self, items: &[(u64, i32)], kind: RowKind) -> Result<Vec<f32>> {
        let Self { cfg, prep, params, qp, pool, seqs, dws, stats, e4m3_lut, .. } = self;
        let rows = items.len();
        if rows == 0 {
            return Ok(Vec::new());
        }
        let (d, f, v) = (cfg.width, cfg.ffn_width(), cfg.vocab);
        let (h, dh) = (cfg.n_heads(), cfg.head_dim);
        let cap = cfg.seq_len;
        let t_start = Instant::now();
        dws.ensure(cfg, rows, cap);
        for (r, (key, tok)) in items.iter().enumerate() {
            block::check_tokens(std::slice::from_ref(tok), cfg.vocab)?;
            // rows of one sequence stack at consecutive positions
            let stacked = items[..r].iter().filter(|(other, _)| other == key).count();
            let seq = seqs.get(key).ok_or_else(|| err!("unknown sequence SeqId({key})"))?;
            let p = seq.len() + stacked;
            if p >= cap {
                bail!("sequence SeqId({key}) is at context capacity {cap}");
            }
            dws.toks[r] = *tok;
            dws.pos[r] = p;
        }
        let pos = &dws.pos[..rows];
        let attn_scale = 1.0 / (dh as f32).sqrt();
        let bpv = pool.bytes_per_value();
        let codec = match pool.mode() {
            KvStoreMode::Bf16 => KvCodec::Bf16,
            KvStoreMode::Fp8E4m3 => KvCodec::Fp8E4m3(&*e4m3_lut),
        };
        let h0 = pool.fp8_health();
        // op-site work counters (closed-form pins live in perfmodel)
        let hidden_per_tok: u64 =
            block::hidden_gemm_shapes(cfg).iter().map(|&(_, o, i)| 2 * (o * i) as u64).sum();
        let mut flops = 0u64;
        let mut kv_written = 0u64;
        let mut kv_read = 0u64;

        block::op_embed(&params[0], &dws.toks[..rows], d, &mut dws.x[..rows * d]);

        for l in 0..cfg.depth {
            let [(a1, b1), (a2, b2)] = prep.coeffs[l];

            // ---- attention branch (same ops as forward_tower) ----------
            match prep.placement {
                NormPlacement::Pre => block::op_rmsnorm(
                    &dws.x[..rows * d],
                    &params[block::idx_g1(l)],
                    d,
                    &mut dws.n[..rows * d],
                    &mut dws.r[..rows],
                    &mut dws.xq[..rows * d],
                ),
                NormPlacement::ResPost => {
                    dws.xq[..rows * d].copy_from_slice(&dws.x[..rows * d]);
                }
            }
            block::observe_cast("qkv", l, &dws.xq[..rows * d], prep.plan.qkv);
            block::op_linear(
                &mut dws.xq[..rows * d],
                prep.plan.qkv,
                &qp.qkv_t[l],
                &mut dws.z_qkv[..rows * 3 * d],
                rows,
                3 * d,
                d,
                prep.alpha_qkv,
            );
            block::quantize_slice(&mut dws.z_qkv[..rows * 3 * d], QuantMode::Bf16);
            block::split_heads_rope_rows(
                &dws.z_qkv[..rows * 3 * d],
                pos,
                cfg,
                &prep.rope_cos,
                &prep.rope_sin,
                &mut dws.q_heads[..rows * d],
                &mut dws.k_heads[..rows * d],
                &mut dws.v_heads[..rows * d],
            );
            block::quantize_slice(&mut dws.q_heads[..rows * d], QuantMode::Bf16);
            block::quantize_slice(&mut dws.k_heads[..rows * d], QuantMode::Bf16);
            block::quantize_slice(&mut dws.v_heads[..rows * d], QuantMode::Bf16);

            // append this position's K/V, then attend over len+1 entries
            // (chunk rows of one sequence are all appended before any row
            // attends, so row r sees every chunk position <= pos[r])
            for (r, (key, _)) in items.iter().enumerate() {
                let seq = seqs
                    .get_mut(key)
                    .ok_or_else(|| err!("sequence SeqId({key}) vanished mid-decode"))?;
                for hh in 0..h {
                    let chain = pool.chain_of(h, l, hh);
                    let o = (r * h + hh) * dh;
                    pool.append(
                        seq,
                        chain,
                        pos[r],
                        &dws.k_heads[o..o + dh],
                        &dws.v_heads[o..o + dh],
                    );
                    kv_written += (2 * dh * bpv) as u64;
                }
            }
            // page lists gathered sequentially into two flat per-layer
            // buffers (2 allocations per layer, not 2 per (seq, head)
            // pair); the parallel kernel below only reads them through
            // the reused `page_bounds` ranges
            let mut kp_flat: Vec<&[u8]> = Vec::with_capacity(2 * rows * h);
            let mut vp_flat: Vec<&[u8]> = Vec::with_capacity(2 * rows * h);
            dws.page_bounds.clear();
            for (r, (key, _)) in items.iter().enumerate() {
                let seq = seqs
                    .get(key)
                    .ok_or_else(|| err!("sequence SeqId({key}) vanished mid-decode"))?;
                let len = pos[r] + 1;
                for hh in 0..h {
                    let start = kp_flat.len();
                    let chain = pool.chain_of(h, l, hh);
                    pool.pages(seq, chain, len, &mut kp_flat, &mut vp_flat);
                    dws.page_bounds.push((start, kp_flat.len()));
                    kv_read += (2 * len * dh * bpv) as u64;
                    flops += 4 * (dh * len) as u64;
                }
            }
            flops += rows as u64 * hidden_per_tok;
            let unit = 2 * cap * dh + cap;
            let q_heads = &dws.q_heads[..rows * d];
            let bounds = &dws.page_bounds;
            let threads =
                parallel::threads_for((rows * h) as u64 * 4 * (cap * dh) as u64);
            parallel::par_join2(
                &mut dws.o_heads[..rows * d],
                &mut dws.attn_scratch[..rows * h * unit],
                dh,
                unit,
                threads,
                |i, oc, sc| {
                    let len = pos[i / h] + 1;
                    let (kf, rest) = sc.split_at_mut(cap * dh);
                    let (vf, scores) = rest.split_at_mut(cap * dh);
                    let (a, b) = bounds[i];
                    attn_decode_cached(
                        &q_heads[i * dh..(i + 1) * dh],
                        &kp_flat[a..b],
                        &vp_flat[a..b],
                        len,
                        dh,
                        attn_scale,
                        codec,
                        kf,
                        vf,
                        scores,
                        oc,
                    );
                },
            );
            drop(kp_flat);
            drop(vp_flat);
            block::merge_heads(&dws.o_heads[..rows * d], cfg, 1, &mut dws.xq[..rows * d]);
            block::observe_cast("attn_out", l, &dws.xq[..rows * d], prep.plan.attn_out);
            block::op_linear(
                &mut dws.xq[..rows * d],
                prep.plan.attn_out,
                &qp.attn_out_t[l],
                &mut dws.t1[..rows * d],
                rows,
                d,
                d,
                prep.alpha_attn_out,
            );
            match prep.placement {
                NormPlacement::Pre => block::residual_combine(
                    &dws.x[..rows * d],
                    &dws.t1[..rows * d],
                    a1,
                    b1,
                    &mut dws.xmid[..rows * d],
                ),
                NormPlacement::ResPost => {
                    block::op_rmsnorm(
                        &dws.t1[..rows * d],
                        &params[block::idx_g1(l)],
                        d,
                        &mut dws.n[..rows * d],
                        &mut dws.r[..rows],
                        &mut dws.t0[..rows * d],
                    );
                    block::residual_combine(
                        &dws.x[..rows * d],
                        &dws.t0[..rows * d],
                        a1,
                        b1,
                        &mut dws.xmid[..rows * d],
                    );
                }
            }

            // ---- ffn branch (same ops as forward_tower) ----------------
            match prep.placement {
                NormPlacement::Pre => block::op_rmsnorm(
                    &dws.xmid[..rows * d],
                    &params[block::idx_g2(l)],
                    d,
                    &mut dws.n[..rows * d],
                    &mut dws.r[..rows],
                    &mut dws.xq[..rows * d],
                ),
                NormPlacement::ResPost => {
                    dws.xq[..rows * d].copy_from_slice(&dws.xmid[..rows * d]);
                }
            }
            block::observe_cast("ffn_up", l, &dws.xq[..rows * d], prep.plan.ffn_up);
            block::op_linear(
                &mut dws.xq[..rows * d],
                prep.plan.ffn_up,
                &qp.ffn_up_t[l],
                &mut dws.z_up[..rows * f],
                rows,
                f,
                d,
                prep.alpha_ffn_up,
            );
            block::apply_act(&dws.z_up[..rows * f], prep.act, &mut dws.xq_down[..rows * f]);
            block::observe_cast("ffn_down", l, &dws.xq_down[..rows * f], prep.plan.ffn_down);
            block::op_linear(
                &mut dws.xq_down[..rows * f],
                prep.plan.ffn_down,
                &qp.ffn_down_t[l],
                &mut dws.t1[..rows * d],
                rows,
                d,
                f,
                prep.alpha_ffn_down,
            );
            match prep.placement {
                NormPlacement::Pre => block::residual_combine(
                    &dws.xmid[..rows * d],
                    &dws.t1[..rows * d],
                    a2,
                    b2,
                    &mut dws.x[..rows * d],
                ),
                NormPlacement::ResPost => {
                    block::op_rmsnorm(
                        &dws.t1[..rows * d],
                        &params[block::idx_g2(l)],
                        d,
                        &mut dws.n[..rows * d],
                        &mut dws.r[..rows],
                        &mut dws.t0[..rows * d],
                    );
                    block::residual_combine(
                        &dws.xmid[..rows * d],
                        &dws.t0[..rows * d],
                        a2,
                        b2,
                        &mut dws.x[..rows * d],
                    );
                }
            }
        }

        // final RMS-norm → BF16 LM-head input → logits
        block::op_rmsnorm(
            &dws.x[..rows * d],
            &params[block::idx_gf(cfg)],
            d,
            &mut dws.n[..rows * d],
            &mut dws.r[..rows],
            &mut dws.y[..rows * d],
        );
        // BF16 rounding fused into the head GEMM's pack step — one sweep
        // over `y` instead of quantize-then-matmul (bit-identical: the
        // BF16 round is elementwise)
        let bf16 = crate::fp8::BF16.fast_caster();
        matmul_bt_quant(
            &mut dws.y[..rows * d],
            &qp.head_t,
            &mut dws.logits[..rows * v],
            rows,
            v,
            d,
            prep.alpha_head,
            |p| bf16.quantize_slice(p),
        );
        flops += rows as u64 * 2 * (d * v) as u64;

        for (key, _) in items {
            seqs.get_mut(key)
                .ok_or_else(|| err!("sequence SeqId({key}) vanished mid-decode"))?
                .advance();
        }
        if pool.mode() == KvStoreMode::Fp8E4m3 && telemetry::enabled() {
            telemetry::record_cast("kv_cache", 0, "e4m3", health_delta(pool.fp8_health(), h0));
        }
        stats.kv_bytes_written += kv_written;
        stats.kv_bytes_read += kv_read;
        match kind {
            RowKind::Decode => {
                stats.decode_flops += flops;
                stats.decode_steps += 1;
                stats.decode_tokens += rows as u64;
                stats.decode_time += t_start.elapsed();
            }
            RowKind::Prefill => {
                stats.prefill_flops += flops;
                stats.prefill_calls += 1;
                stats.prefill_tokens += rows as u64;
                stats.prefill_time += t_start.elapsed();
            }
        }
        Ok(dws.logits[..rows * v].to_vec())
    }
}

/// Per-call counter delta of the pool's cumulative FP8 KV cast health
/// (what one prefill/decode execute just encoded).
fn health_delta(now: CastHealth, before: CastHealth) -> CastHealth {
    CastHealth {
        total: now.total - before.total,
        nonzero: now.nonzero - before.nonzero,
        underflow_to_zero: now.underflow_to_zero - before.underflow_to_zero,
        saturated: now.saturated - before.saturated,
        overflow_nonfinite: now.overflow_nonfinite - before.overflow_nonfinite,
        subnormal: now.subnormal - before.subnormal,
    }
}

// ---------------------------------------------------------------------------
// Sampling

/// Greedy sampling: lowest-index argmax (deterministic under ties).
pub fn sample_greedy(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Seeded top-k sampling: softmax over the `k` highest logits at
/// `temperature`, sampled with the caller's RNG. Candidate order (logit
/// descending, index ascending on ties) and the f64 cumulative sum are
/// fixed, so the draw is a pure function of `(logits, k, temperature,
/// rng state)`. `k <= 1` degenerates to greedy.
pub fn sample_topk(logits: &[f32], k: usize, temperature: f32, rng: &mut Rng) -> i32 {
    if k <= 1 || logits.len() <= 1 {
        return sample_greedy(logits);
    }
    let k = k.min(logits.len());
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(a.cmp(&b)));
    idx.truncate(k);
    let t = (temperature.max(1e-6)) as f64;
    let m = logits[idx[0]] as f64;
    let weights: Vec<f64> =
        idx.iter().map(|&i| ((logits[i] as f64 - m) / t).exp()).collect();
    let total: f64 = weights.iter().sum();
    let u = rng.f64() * total;
    let mut acc = 0f64;
    for (w, &i) in weights.iter().zip(&idx) {
        acc += w;
        if u < acc {
            return i as i32;
        }
    }
    idx[k - 1] as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::with_max_threads;

    fn lane_cfg(variant: &str, precision: &str) -> ModelConfig {
        let residual = if variant == "mus" { "fixed" } else { "standard" };
        ModelConfig {
            width: 16,
            depth: 2,
            head_dim: 8,
            vocab: 64,
            seq_len: 16,
            batch: 2,
            variant: variant.into(),
            precision: precision.into(),
            residual: residual.into(),
            ..ModelConfig::default()
        }
    }

    fn tokens_for(cfg: &ModelConfig, mul: usize) -> Vec<i32> {
        (0..cfg.batch * cfg.seq_len).map(|i| ((i * mul + 1) % cfg.vocab) as i32).collect()
    }

    fn session_for(cfg: &ModelConfig, tau: f32, seed: i32) -> (InferSession, Vec<Vec<f32>>) {
        let params = block::init_params(cfg, seed);
        let sess = InferSession::from_params(cfg, params.clone(), tau).unwrap();
        (sess, params)
    }

    fn fwd_logits(cfg: &ModelConfig, params: &[Vec<f32>], tokens: &[i32], tau: f32) -> Vec<f32> {
        let prep = Prepared::new(cfg, tau).unwrap();
        block::forward_logits(cfg, &prep, params, tokens).unwrap()
    }

    /// Acceptance: prefill IS the training forward — bit-identical logits
    /// for every sequence of the batch, µS static-FP8 and BF16 plans.
    #[test]
    fn prefill_logits_bit_identical_to_training_fwd() {
        for precision in ["fp8", "bf16"] {
            let cfg = lane_cfg("mus", precision);
            let tau = 0.4f32;
            let (mut sess, params) = session_for(&cfg, tau, 7);
            let tokens = tokens_for(&cfg, 5);
            let full = fwd_logits(&cfg, &params, &tokens, tau);
            let (s, v) = (cfg.seq_len, cfg.vocab);
            for b in 0..cfg.batch {
                let id = sess.add_sequence();
                let got = sess.prefill(id, &tokens[b * s..(b + 1) * s]).unwrap();
                let want = &full[b * s * v..(b + 1) * s * v];
                assert_eq!(got.len(), want.len());
                for (i, (g, w)) in got.iter().zip(want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "mus+{precision} seq {b} logit {i}: prefill {g} vs fwd {w}"
                    );
                }
            }
        }
    }

    /// The numerics-match claim, end to end: every KV-cache decode step
    /// reproduces the matching training-forward logits row bit for bit
    /// (µS static FP8 and BF16; the cache stores BF16, which the tower's
    /// post-RoPE rounding makes lossless).
    #[test]
    fn decode_steps_bit_identical_to_training_fwd_rows() {
        for precision in ["fp8", "bf16"] {
            let cfg = lane_cfg("mus", precision);
            let tau = 0.4f32;
            let (mut sess, params) = session_for(&cfg, tau, 11);
            let tokens = tokens_for(&cfg, 7);
            let full = fwd_logits(&cfg, &params, &tokens, tau);
            let (s, v) = (cfg.seq_len, cfg.vocab);
            let id = sess.add_sequence();
            for t in 0..s {
                let got = sess.decode_step(id, tokens[t]).unwrap();
                let want = &full[t * v..(t + 1) * v];
                for (i, (g, w)) in got.iter().zip(want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "mus+{precision} pos {t} logit {i}: decode {g} vs fwd {w}"
                    );
                }
            }
            assert_eq!(sess.sequence_len(id).unwrap(), s);
        }
    }

    /// Mixed prefill + decode (the serving shape): prompt via prefill,
    /// continue via decode — still bit-identical to the full forward.
    #[test]
    fn prefill_then_decode_matches_full_forward() {
        let cfg = lane_cfg("mus", "fp8");
        let tau = 0.4f32;
        let (mut sess, params) = session_for(&cfg, tau, 3);
        let tokens = tokens_for(&cfg, 5);
        let (s, v) = (cfg.seq_len, cfg.vocab);
        let full = fwd_logits(&cfg, &params, &tokens, tau);
        let split = s / 2;
        let id = sess.add_sequence();
        let pre = sess.prefill(id, &tokens[..split]).unwrap();
        assert_eq!(
            pre[(split - 1) * v..split * v]
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<_>>(),
            full[(split - 1) * v..split * v].iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        for t in split..s {
            let got = sess.decode_step(id, tokens[t]).unwrap();
            let want = &full[t * v..(t + 1) * v];
            for (g, w) in got.iter().zip(want) {
                assert_eq!(g.to_bits(), w.to_bits(), "pos {t} after prefill split {split}");
            }
        }
    }

    /// Batched decode is row-local for static plans: sequences decoded
    /// together get exactly the tokens they'd get alone.
    #[test]
    fn batched_decode_matches_isolated_sequences() {
        let cfg = lane_cfg("mus", "fp8");
        let (mut sess, params) = session_for(&cfg, 0.4, 5);
        let tokens = tokens_for(&cfg, 3);
        let s = cfg.seq_len;
        // isolated: each sequence alone in its own session
        let mut alone = Vec::new();
        for b in 0..cfg.batch {
            let mut solo = InferSession::from_params(&cfg, params.clone(), 0.4).unwrap();
            let id = solo.add_sequence();
            let mut outs = Vec::new();
            for t in 0..s / 2 {
                outs.push(solo.decode_step(id, tokens[b * s + t]).unwrap());
            }
            alone.push(outs);
        }
        // batched: all sequences in one decode execute per step
        let ids: Vec<SeqId> = (0..cfg.batch).map(|_| sess.add_sequence()).collect();
        for t in 0..s / 2 {
            let items: Vec<(SeqId, i32)> =
                ids.iter().enumerate().map(|(b, &id)| (id, tokens[b * s + t])).collect();
            let outs = sess.decode_batch(&items).unwrap();
            for (b, got) in outs.iter().enumerate() {
                for (i, (g, w)) in got.iter().zip(&alone[b][t]).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "seq {b} step {t} logit {i}");
                }
            }
        }
        assert_eq!(sess.stats().decode_steps, s / 2);
        assert_eq!(sess.stats().decode_tokens, (s / 2 * cfg.batch) as u64);
    }

    /// Greedy decode is bit-deterministic at any worker-thread count
    /// (the satellite acceptance: 1 vs 2 vs 4 threads).
    #[test]
    fn greedy_decode_invariant_across_thread_counts() {
        // wide enough that the prefill GEMMs clear the parallel threshold
        let cfg = ModelConfig {
            width: 64,
            depth: 2,
            head_dim: 8,
            vocab: 128,
            seq_len: 32,
            batch: 1,
            ..ModelConfig::default()
        };
        let params = block::init_params(&cfg, 9);
        let prompt: Vec<i32> = (0..8).map(|i| (i * 11 % cfg.vocab) as i32).collect();
        let run = |threads: usize| {
            with_max_threads(threads, || {
                let mut sess =
                    InferSession::from_params(&cfg, params.clone(), 0.4).unwrap();
                let id = sess.add_sequence();
                let logits = sess.prefill(id, &prompt).unwrap();
                let mut tok = sample_greedy(&logits[logits.len() - cfg.vocab..]);
                let mut out = vec![tok];
                for _ in 0..12 {
                    let l = sess.decode_step(id, tok).unwrap();
                    tok = sample_greedy(&l);
                    out.push(tok);
                }
                out
            })
        };
        let t1 = run(1);
        assert_eq!(t1, run(2), "2-thread greedy decode drifted");
        assert_eq!(t1, run(4), "4-thread greedy decode drifted");
    }

    /// SP+FP8's forward path IS still guarded exactly: at batch-1
    /// geometry prefill and the `fwd` artifact run identical tensor
    /// shapes, so even dynamic per-tensor amaxes coincide and the logits
    /// are bit-identical.
    #[test]
    fn sp_dynamic_prefill_matches_fwd_at_batch_one() {
        let cfg = ModelConfig { batch: 1, ..lane_cfg("sp", "fp8") };
        let (mut sess, params) = session_for(&cfg, 0.0, 6);
        let tokens: Vec<i32> =
            (0..cfg.seq_len).map(|i| ((i * 3 + 1) % cfg.vocab) as i32).collect();
        let full = fwd_logits(&cfg, &params, &tokens, 0.0);
        let id = sess.add_sequence();
        let got = sess.prefill(id, &tokens).unwrap();
        assert_eq!(got.len(), full.len());
        for (i, (g, w)) in got.iter().zip(&full).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "sp+fp8 batch-1 logit {i}");
        }
    }

    /// SP+FP8 (dynamic scaling) decodes finite logits — no bit-match
    /// guarantee (its per-tensor amax depends on batch composition).
    #[test]
    fn sp_dynamic_lane_decodes_finite() {
        let cfg = lane_cfg("sp", "fp8");
        let (mut sess, _) = session_for(&cfg, 0.0, 2);
        let id = sess.add_sequence();
        let l = sess.prefill(id, &[1, 2, 3, 4]).unwrap();
        assert!(l.iter().all(|x| x.is_finite()));
        let l = sess.decode_step(id, 5).unwrap();
        assert!(l.iter().all(|x| x.is_finite()));
        assert_eq!(sess.sequence_len(id).unwrap(), 5);
    }

    #[test]
    fn cache_accounting_and_eviction() {
        let cfg = lane_cfg("mus", "fp8");
        let (mut sess, _) = session_for(&cfg, 0.4, 1);
        assert_eq!(sess.kv_slabs_in_use(), 0);
        let a = sess.add_sequence();
        sess.prefill(a, &[1, 2, 3]).unwrap();
        let after_a = sess.kv_slabs_in_use();
        // every (layer, head) chain holds exactly one slab at len 3
        assert_eq!(after_a, cfg.depth * cfg.n_heads());
        let b = sess.add_sequence();
        sess.prefill(b, &[4, 5]).unwrap();
        assert_eq!(sess.kv_slabs_in_use(), 2 * after_a);
        assert!(sess.kv_bytes_in_use() > 0);
        sess.free_sequence(a).unwrap();
        assert_eq!(sess.kv_slabs_in_use(), after_a);
        assert_eq!(sess.live_sequences(), 1);
        assert!(sess.free_sequence(a).is_err(), "double free must error");
    }

    #[test]
    fn decode_guards_capacity_duplicates_and_bad_tokens() {
        let cfg = lane_cfg("mus", "fp8");
        let (mut sess, _) = session_for(&cfg, 0.4, 1);
        let id = sess.add_sequence();
        assert!(sess.decode_step(id, cfg.vocab as i32).is_err(), "oov token");
        assert!(sess.decode_batch(&[(id, 1), (id, 2)]).is_err(), "duplicate sequence");
        for t in 0..cfg.seq_len {
            sess.decode_step(id, (t % cfg.vocab) as i32).unwrap();
        }
        assert!(sess.decode_step(id, 0).is_err(), "context capacity");
        // prefill on a populated sequence is an error
        assert!(sess.prefill(id, &[1]).is_err());
    }

    #[test]
    fn sampling_greedy_and_topk_are_deterministic() {
        let logits = [0.1f32, 2.0, 2.0, -1.0];
        assert_eq!(sample_greedy(&logits), 1, "ties resolve to the lowest index");
        let mut rng = Rng::new(42);
        assert_eq!(sample_topk(&logits, 1, 1.0, &mut rng), 1, "k=1 is greedy");
        // seeded top-k: identical streams give identical draws
        let draws = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| sample_topk(&logits, 3, 1.0, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draws(7), draws(7));
        // only top-k candidates are ever drawn, and the mode is the argmax
        let d = draws(9);
        assert!(d.iter().all(|&t| t == 1 || t == 2 || t == 0));
        let ones = d.iter().filter(|&&t| t == 1).count();
        let zeros = d.iter().filter(|&&t| t == 0).count();
        assert!(ones >= zeros, "argmax should dominate draws: {d:?}");
    }

    /// Satellite acceptance: chunked prefill is bit-identical to
    /// whole-prompt prefill for chunk sizes {1, SLAB_TOKENS−1,
    /// SLAB_TOKENS, prompt_len}, both plans, 1/2/4 worker threads.
    #[test]
    fn chunked_prefill_bit_identical_to_whole_prompt() {
        use crate::runtime::kvcache::SLAB_TOKENS;
        for precision in ["fp8", "bf16"] {
            let cfg = ModelConfig { seq_len: 40, ..lane_cfg("mus", precision) };
            let params = block::init_params(&cfg, 13);
            let prompt: Vec<i32> =
                (0..cfg.seq_len).map(|i| ((i * 7 + 2) % cfg.vocab) as i32).collect();
            // reference: whole-prompt prefill (the training tower)
            let mut base = InferSession::from_params(&cfg, params.clone(), 0.4).unwrap();
            let id = base.add_sequence();
            let want = base.prefill(id, &prompt).unwrap();
            for threads in [1usize, 2, 4] {
                for chunk in [1usize, SLAB_TOKENS - 1, SLAB_TOKENS, prompt.len()] {
                    let got = with_max_threads(threads, || {
                        let mut sess =
                            InferSession::from_params(&cfg, params.clone(), 0.4).unwrap();
                        let id = sess.add_sequence();
                        let mut out = Vec::new();
                        for c in prompt.chunks(chunk) {
                            out.extend(sess.prefill_chunk(id, c).unwrap());
                        }
                        assert_eq!(sess.sequence_len(id).unwrap(), prompt.len());
                        out
                    });
                    assert_eq!(got.len(), want.len());
                    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "mus+{precision} chunk {chunk} threads {threads} logit {i}"
                        );
                    }
                }
            }
        }
    }

    /// Satellite acceptance: prefix-cache adoption (shared full slabs +
    /// copied partial tail) leaves the numerics bit-identical to a
    /// cache-off session, and evicting the donor never frees slabs the
    /// index and adopter still hold.
    #[test]
    fn prefix_adoption_bit_identical_and_eviction_respects_sharing() {
        use crate::runtime::kvcache::SLAB_TOKENS;
        let cfg = ModelConfig { seq_len: 48, ..lane_cfg("mus", "fp8") };
        let params = block::init_params(&cfg, 17);
        let prefix: Vec<i32> =
            (0..SLAB_TOKENS + 4).map(|i| ((i * 5 + 1) % cfg.vocab) as i32).collect();
        let mut prompt = prefix.clone();
        prompt.extend([7, 9, 11]);
        // reference: plain session, no prefix cache
        let mut plain = InferSession::from_params(&cfg, params.clone(), 0.4).unwrap();
        let pid = plain.add_sequence();
        let want = plain.prefill(pid, &prompt).unwrap();
        let v = cfg.vocab;

        let mut sess = InferSession::from_params(&cfg, params, 0.4).unwrap();
        sess.enable_prefix_cache(8);
        // donor request caches and indexes the shared prefix
        let donor = sess.add_sequence();
        sess.prefill(donor, &prefix).unwrap();
        sess.insert_prefix(donor, &prefix).unwrap();
        // adopter shares the full slab, copies the 4-row tail, computes
        // only the suffix
        let adopter = sess.add_sequence();
        let m = sess.adopt_prefix(adopter, &prompt).unwrap();
        assert_eq!(m, prefix.len());
        assert_eq!(sess.stats().prefix_hits, 1);
        assert_eq!(sess.stats().prefix_hit_tokens, m as u64);
        assert!(sess.stats().kv_bytes_copied > 0, "partial tail must be copied");
        let got = sess.prefill_chunk(adopter, &prompt[m..]).unwrap();
        // the adopted run's suffix logits match the cache-off run bitwise
        for (i, (g, w)) in got.iter().zip(&want[m * v..]).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "adopted suffix logit {i}");
        }
        // prefill only computed the suffix (the tentpole's point)
        assert_eq!(
            sess.stats().prefill_tokens,
            (prefix.len() + (prompt.len() - m)) as u64,
            "cached positions must not be recomputed"
        );
        // decode after adoption stays bit-identical to the plain session
        let a = sess.decode_step(adopter, 3).unwrap();
        let b = plain.decode_step(pid, 3).unwrap();
        for (g, w) in a.iter().zip(&b) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        // donor eviction drops refcounts but the index still holds every
        // donor slab: slabs_in_use must not change, and shared reads must
        // stay intact
        let before = sess.kv_slabs_in_use();
        sess.free_sequence(donor).unwrap();
        assert_eq!(sess.kv_slabs_in_use(), before, "shared slabs freed on eviction");
        let a2 = sess.decode_step(adopter, 5).unwrap();
        let b2 = plain.decode_step(pid, 5).unwrap();
        for (g, w) in a2.iter().zip(&b2) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    /// Tentpole acceptance: the E4M3 KV store halves cache bytes exactly,
    /// records zero saturation under µS (the static scale-1.0 proof), and
    /// its decode logits stay within a measured divergence bound of the
    /// BF16 cache on an identical token stream.
    #[test]
    fn fp8_kv_cache_halves_bytes_with_bounded_divergence() {
        let cfg = lane_cfg("mus", "fp8");
        let params = block::init_params(&cfg, 21);
        let prompt: Vec<i32> = (0..8).map(|i| ((i * 3 + 1) % cfg.vocab) as i32).collect();
        let feed: Vec<i32> = (0..6).map(|t| ((t * 5 + 2) % cfg.vocab) as i32).collect();
        let run = |mode: KvStoreMode| {
            let mut sess = InferSession::from_params(&cfg, params.clone(), 0.4).unwrap();
            sess.set_kv_store_mode(mode).unwrap();
            let id = sess.add_sequence();
            let pre = sess.prefill(id, &prompt).unwrap();
            // identical forced token stream in both modes, so rows compare
            let mut rows = vec![pre[(prompt.len() - 1) * cfg.vocab..].to_vec()];
            for &t in &feed {
                rows.push(sess.decode_step(id, t).unwrap());
            }
            let stats = sess.stats().clone();
            (rows, stats, sess.kv_bytes_in_use(), sess.fp8_kv_health(),
             sess.fp8_kv_saturated_slabs())
        };
        let (bf, sb, ub, hb, _) = run(KvStoreMode::Bf16);
        let (f8, sf, uf, hf, sat) = run(KvStoreMode::Fp8E4m3);
        // prefill logits come from the tower (no cache read): bit-equal
        for (g, w) in f8[0].iter().zip(&bf[0]) {
            assert_eq!(g.to_bits(), w.to_bits(), "prefill row must not depend on KV codec");
        }
        // exact byte halving, both written and resident
        assert_eq!(sb.kv_bytes_written, 2 * sf.kv_bytes_written);
        assert_eq!(sb.kv_bytes_read, 2 * sf.kv_bytes_read);
        assert_eq!(ub, 2 * uf);
        // µS unit-variance K/V: static scale 1.0 saturates nothing
        assert!(hf.total > 0);
        assert_eq!(hf.saturated, 0, "µS FP8 KV must not saturate");
        assert_eq!(sat, 0);
        assert_eq!(hb.total, 0, "bf16 mode records no fp8 casts");
        // measured logit-divergence bound vs the BF16 cache
        let mut max_diff = 0f32;
        let mut max_mag = 0f32;
        for (a, b) in bf.iter().zip(&f8) {
            for (x, y) in a.iter().zip(b) {
                assert!(y.is_finite());
                max_diff = max_diff.max((x - y).abs());
                max_mag = max_mag.max(x.abs());
            }
        }
        assert!(
            max_diff <= 0.5 * max_mag.max(1.0),
            "FP8 KV divergence {max_diff} vs logit magnitude {max_mag}"
        );
    }

    /// FP8 KV appends surface in telemetry under the "kv_cache" op when
    /// a capture is active (and only then).
    #[test]
    fn fp8_kv_health_flows_into_telemetry() {
        let cfg = lane_cfg("mus", "fp8");
        let (_, report) = crate::telemetry::capture(|| {
            let params = block::init_params(&cfg, 25);
            let mut sess = InferSession::from_params(&cfg, params, 0.4).unwrap();
            sess.set_kv_store_mode(KvStoreMode::Fp8E4m3).unwrap();
            let id = sess.add_sequence();
            sess.prefill(id, &[1, 2, 3]).unwrap();
            sess.decode_step(id, 4).unwrap();
        });
        let totals = report.cast_totals("kv_cache").expect("kv_cache casts recorded");
        // 3 prefill + 1 decode positions, 2·head_dim values per chain
        assert_eq!(totals.total, (4 * cfg.depth * cfg.n_heads() * 2 * cfg.head_dim) as u64);
        assert_eq!(totals.saturated, 0);
    }

    /// The acceptance pin: every live op-site counter equals its
    /// independently derived perfmodel/ModelConfig closed form, exactly —
    /// tower prefill, chunked prefill, prefix-adopted prefill, decode.
    #[test]
    fn live_counters_exact_match_closed_forms() {
        use crate::perfmodel;
        let cfg = lane_cfg("mus", "fp8");
        // tower prefill of p tokens, then 4 decode steps
        let (mut sess, _) = session_for(&cfg, 0.4, 23);
        let id = sess.add_sequence();
        let p = 5usize;
        let prompt: Vec<i32> = (0..p as i32).collect();
        sess.prefill(id, &prompt).unwrap();
        assert_eq!(sess.stats().prefill_flops, perfmodel::prefill_flops(&cfg, p, 0));
        assert_eq!(sess.stats().kv_bytes_written, cfg.kv_cache_bytes_per_token() * p as u64);
        assert_eq!(sess.stats().kv_bytes_read, 0, "tower prefill reads no cache");
        let mut want_read = 0u64;
        let mut want_flops = 0u64;
        for t in 0..4usize {
            sess.decode_step(id, t as i32).unwrap();
            want_read += cfg.kv_cache_bytes_read_per_token(p + t + 1);
            want_flops += perfmodel::decode_flops_per_token(&cfg, p + t + 1);
        }
        assert_eq!(sess.stats().kv_bytes_read, want_read);
        assert_eq!(sess.stats().decode_flops, want_flops);
        assert_eq!(
            sess.stats().kv_bytes_written,
            cfg.kv_cache_bytes_per_token() * (p as u64 + 4)
        );
        // chunked prefill: any chunking sums to the same closed form
        let (mut s2, _) = session_for(&cfg, 0.4, 29);
        let id2 = s2.add_sequence();
        let n = 7usize;
        let prompt2: Vec<i32> = (0..n as i32).collect();
        for c in prompt2.chunks(3) {
            s2.prefill_chunk(id2, c).unwrap();
        }
        assert_eq!(s2.stats().prefill_flops, perfmodel::prefill_flops(&cfg, n, 0));
        assert_eq!(s2.stats().kv_bytes_read, perfmodel::prefill_kv_bytes_read(&cfg, n, 0, 2));
        assert_eq!(s2.stats().kv_bytes_written, cfg.kv_cache_bytes_per_token() * n as u64);
        // prefix-adopted prefill: n new rows on m cached positions
        let (mut s3, _) = session_for(&cfg, 0.4, 31);
        s3.enable_prefix_cache(4);
        let donor = s3.add_sequence();
        let shared: Vec<i32> = (0..4).collect();
        s3.prefill(donor, &shared).unwrap();
        s3.insert_prefix(donor, &shared).unwrap();
        let base_flops = s3.stats().prefill_flops;
        let base_read = s3.stats().kv_bytes_read;
        let adopter = s3.add_sequence();
        let mut longer = shared.clone();
        longer.extend([9, 10, 11]);
        let m = s3.adopt_prefix(adopter, &longer).unwrap();
        assert_eq!(m, shared.len());
        s3.prefill_chunk(adopter, &longer[m..]).unwrap();
        let new = longer.len() - m;
        assert_eq!(
            s3.stats().prefill_flops - base_flops,
            perfmodel::prefill_flops(&cfg, new, m),
            "adopted-prefill FLOPs"
        );
        assert_eq!(
            s3.stats().kv_bytes_read - base_read,
            perfmodel::prefill_kv_bytes_read(&cfg, new, m, 2),
            "adopted-prefill KV reads"
        );
    }

    /// `kv_trim` releases free slab buffers between bursts; high-water
    /// tracking survives, and in-use slabs are untouchable.
    #[test]
    fn kv_trim_and_high_water_accounting() {
        let cfg = lane_cfg("mus", "fp8");
        let (mut sess, _) = session_for(&cfg, 0.4, 33);
        let id = sess.add_sequence();
        sess.prefill(id, &(0..12).collect::<Vec<i32>>()).unwrap();
        let peak = sess.kv_materialized_bytes();
        assert_eq!(sess.kv_high_water_bytes(), peak);
        sess.free_sequence(id).unwrap();
        assert_eq!(sess.kv_materialized_bytes(), peak, "free list keeps buffers");
        sess.kv_trim(0);
        assert_eq!(sess.kv_materialized_bytes(), 0, "trim releases free buffers");
        assert_eq!(sess.kv_high_water_bytes(), peak, "high-water survives trim");
        // a new burst rematerializes and still decodes correctly
        let id2 = sess.add_sequence();
        sess.prefill(id2, &[1, 2, 3]).unwrap();
        assert!(sess.decode_step(id2, 4).unwrap().iter().all(|x| x.is_finite()));
        sess.kv_trim(0);
        assert_eq!(
            sess.kv_materialized_bytes(),
            sess.kv_bytes_in_use(),
            "trim never touches in-use slabs"
        );
        // mode switches are guarded while sequences are live
        assert!(sess.set_kv_store_mode(KvStoreMode::Fp8E4m3).is_err());
        sess.free_sequence(id2).unwrap();
        assert!(sess.set_kv_store_mode(KvStoreMode::Fp8E4m3).is_ok());
        assert_eq!(sess.kv_store_mode(), KvStoreMode::Fp8E4m3);
    }
}
