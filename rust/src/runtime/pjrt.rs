//! PJRT backend (feature `pjrt`): AOT HLO-text artifacts on the PJRT CPU
//! client via the `xla` crate.
//!
//! Facts this wrapper encodes (verified by `rust/src/bin/hlo_check.rs` and
//! the artifact-gated integration tests):
//!
//!  - artifacts are HLO *text*; `HloModuleProto::from_text_file` reassigns
//!    instruction ids (jax >= 0.5 emits 64-bit ids that XLA 0.5.1 rejects
//!    in proto form);
//!  - executables built with `return_tuple=True` give back ONE tuple
//!    buffer per replica — PJRT 0.5.1 does not untuple;
//!  - calling `to_vec` on a tuple literal CHECK-fails (aborts), so the
//!    tuple must be `decompose_tuple`d after a single host transfer.
//!
//! Thread-safety model: the `xla` crate's client/executable/buffer types
//! are `Rc`-based and thread-affine, so this backend keeps a *per-thread*
//! client and compile cache (`thread_local!`) behind a shared manifest and
//! mutex-guarded stats — each sweep worker thread compiles once and runs
//! independently. Tensor handles live in a host-side store: PJRT-CPU
//! "device" memory is host memory (`execute` copies in/out regardless), so
//! residency here buys API uniformity rather than copies; on a real
//! accelerator backend the same handles would wrap device buffers.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, Shape, XlaComputation};

use super::backend::{Backend, ExecStats, HandleStore, TensorHandle};
use super::manifest::Manifest;
use super::tensor::Tensor;
use crate::bail;
use crate::util::error::{Context, Result};

thread_local! {
    static CLIENT: RefCell<Option<Rc<PjRtClient>>> = const { RefCell::new(None) };
    // Keyed by (backend instance id, artifact name): two PjrtBackends over
    // different artifact directories must not share compiled programs.
    static EXES: RefCell<HashMap<(u64, String), Rc<PjRtLoadedExecutable>>> =
        RefCell::new(HashMap::new());
}

/// Unique id per PjrtBackend instance (scopes the thread-local exe cache).
static INSTANCE_IDS: AtomicU64 = AtomicU64::new(1);

fn thread_client() -> Result<Rc<PjRtClient>> {
    CLIENT.with(|c| {
        let mut slot = c.borrow_mut();
        if let Some(cl) = slot.as_ref() {
            return Ok(cl.clone());
        }
        let cl = Rc::new(PjRtClient::cpu().context("starting PJRT CPU client")?);
        *slot = Some(cl.clone());
        Ok(cl)
    })
}

fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    match t.dtype() {
        super::manifest::Dtype::F32 => {
            let data = t.as_f32()?;
            if t.shape().is_empty() {
                return Ok(Literal::scalar(data[0]));
            }
            Ok(Literal::vec1(data).reshape(&dims).context("reshaping f32 literal")?)
        }
        super::manifest::Dtype::I32 => {
            let data = t.as_i32()?;
            if t.shape().is_empty() {
                return Ok(Literal::scalar(data[0]));
            }
            Ok(Literal::vec1(data).reshape(&dims).context("reshaping i32 literal")?)
        }
    }
}

fn literal_to_tensor(lit: &Literal) -> Result<Tensor> {
    let shape: Vec<usize> = match lit.array_shape() {
        Ok(s) => s.dims().iter().map(|&d| d as usize).collect(),
        Err(_) => vec![],
    };
    if let Ok(v) = lit.to_vec::<f32>() {
        return Tensor::f32(v, &shape);
    }
    let v = lit.to_vec::<i32>().context("literal is neither f32 nor i32")?;
    Tensor::i32(v, &shape)
}

/// PJRT CPU execution backend over a compiled-artifact directory.
pub struct PjrtBackend {
    instance: u64,
    manifest: Manifest,
    store: HandleStore,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl PjrtBackend {
    /// Open the AOT artifact catalogue in `artifact_dir`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifact_dir.as_ref())
            .context("loading artifacts/manifest.json (run `make artifacts`)")?;
        Ok(PjrtBackend {
            instance: INSTANCE_IDS.fetch_add(1, Ordering::Relaxed),
            manifest,
            store: HandleStore::new(),
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from this thread's cache) an artifact.
    fn cached(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        let key = (self.instance, name.to_string());
        if let Some(e) = EXES.with(|m| m.borrow().get(&key).cloned()) {
            return Ok(e);
        }
        let meta = self
            .manifest
            .find(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        let path = self.manifest.dir.join(&meta.file);
        let path_str = path
            .to_str()
            .with_context(|| format!("non-UTF-8 artifact path {}", path.display()))?;
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(thread_client()?.compile(&comp).with_context(|| format!("compiling {name}"))?);
        let compile_time = t0.elapsed();
        EXES.with(|m| m.borrow_mut().insert(key, exe.clone()));
        self.stats
            .lock()
            .expect("stats lock")
            .entry(name.to_string())
            .or_default()
            .compile_time += compile_time;
        Ok(exe)
    }
}

impl Drop for PjrtBackend {
    fn drop(&mut self) {
        // purge this instance's compiled executables from the dropping
        // thread's cache. Entries compiled on *other* worker threads are
        // reclaimed when those threads exit (thread_local teardown) — the
        // instance-id key guarantees they can never be reused either way.
        let instance = self.instance;
        EXES.with(|m| m.borrow_mut().retain(|(id, _), _| *id != instance));
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        thread_client().map(|c| c.platform_name()).unwrap_or_else(|_| "pjrt".into())
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn upload(&self, t: &Tensor) -> Result<TensorHandle> {
        Ok(self.store.insert(t.clone()))
    }

    fn execute(&self, name: &str, inputs: &[TensorHandle]) -> Result<Vec<TensorHandle>> {
        let meta = self
            .manifest
            .find(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        if inputs.len() != meta.inputs.len() {
            bail!("artifact '{name}' expects {} inputs, got {}", meta.inputs.len(), inputs.len());
        }
        let host: Vec<Arc<Tensor>> = self.store.fetch(inputs, name)?;
        let exe = self.cached(name)?;
        let client = thread_client()?;
        // t0..t1: host->device staging (on PJRT-CPU this includes the full
        // input literal conversion — the honest per-step transfer cost);
        // t1..t2: execution; t2..t3: device->host result transfer.
        let t0 = Instant::now();
        // NOTE: we deliberately avoid `PjRtLoadedExecutable::execute`
        // (literal inputs): its C++ shim `release()`s the device buffers it
        // creates for the inputs and never frees them — a ~full-state leak
        // per training step (measured: 36 GB RSS in an hour-long figure
        // run; see EXPERIMENTS.md §Perf). Instead we create owned buffers
        // and use `execute_b`, which borrows them; they drop right after.
        let mut lits = Vec::with_capacity(host.len());
        for t in &host {
            lits.push(tensor_to_literal(t)?);
        }
        let mut bufs = Vec::with_capacity(lits.len());
        for l in &lits {
            bufs.push(
                client
                    .buffer_from_host_literal(None, l)
                    .with_context(|| format!("staging input for '{name}'"))?,
            );
        }
        let t1 = Instant::now();
        let result = exe.execute_b(&bufs).with_context(|| format!("executing '{name}'"))?;
        drop(bufs);
        let t2 = Instant::now();
        let buf = &result[0][0];
        let mut lit = buf.to_literal_sync().context("transferring result tuple")?;
        let outs = match lit.shape().context("result shape")? {
            Shape::Tuple(_) => lit.decompose_tuple().context("decomposing result tuple")?,
            _ => vec![lit],
        };
        let t3 = Instant::now();
        if outs.len() != meta.outputs.len() {
            bail!(
                "artifact '{name}' declared {} outputs, produced {}",
                meta.outputs.len(),
                outs.len()
            );
        }
        let mut tensors = Vec::with_capacity(outs.len());
        for l in &outs {
            tensors.push(literal_to_tensor(l)?);
        }
        let mut bytes: u64 = host.iter().map(|t| t.byte_len() as u64).sum();
        let mut handles = Vec::with_capacity(tensors.len());
        for t in tensors {
            bytes += t.byte_len() as u64;
            handles.push(self.store.insert(t));
        }
        {
            let mut stats = self.stats.lock().expect("stats lock");
            let s = stats.entry(name.to_string()).or_default();
            s.calls += 1;
            s.execute_time += t2 - t1;
            s.transfer_time += (t1 - t0) + (t3 - t2);
            s.transfer_bytes += bytes;
        }
        Ok(handles)
    }

    fn download(&self, h: &TensorHandle) -> Result<Tensor> {
        self.store.get(h)
    }

    fn free(&self, h: &TensorHandle) {
        self.store.remove(h);
    }

    fn precompile(&self, name: &str) -> Result<()> {
        self.cached(name).map(|_| ())
    }

    fn stats(&self, name: &str) -> Option<ExecStats> {
        self.stats.lock().expect("stats lock").get(name).cloned()
    }
}
