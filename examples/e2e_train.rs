//! End-to-end headline driver (deliverable (b)/EXPERIMENTS.md §E2E):
//! train the ~12M-parameter µS model in *simulated FP8* for a few hundred
//! steps on the synthetic corpus, log the loss curve, compare against the
//! BF16 twin, and run the FP8 (W8A8-analog) eval suite on the result.
//!
//! ```sh
//! cargo run --release --example e2e_train -- [steps]
//! ```
//!
//! This is the CPU-feasible stand-in for the paper's 1B-13B runs (DESIGN.md
//! substitution table): identical code path, shrunk shapes.

use munit::config::ModelConfig;
use munit::eval::evaluate;
use munit::repro::{self, corpus_for, proxy_tc, Ctx};
use munit::scaling::recommended_tau;
use munit::util::error::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let ctx = Ctx::new("artifacts".as_ref(), "results".as_ref(), false)?;

    let cfg8 = ModelConfig {
        width: 384,
        depth: 6,
        head_dim: 64,
        vocab: 2048,
        seq_len: 256,
        batch: 8,
        ..ModelConfig::default()
    };
    let cfg16 = ModelConfig { precision: "bf16".into(), ..cfg8.clone() };
    let tau = recommended_tau(cfg8.depth);
    let tc = proxy_tc(steps, 1.0 / 64.0, 2.0 / 16384.0, tau, 42);

    println!("e2e: µS FP8, {} params, {} steps, {} tokens/step",
        cfg8.n_params(), steps, cfg8.batch * cfg8.seq_len);
    let (r8, state8) = repro::train_with_state(&ctx, &cfg8, &tc)?;
    println!("e2e: µS BF16 baseline…");
    let r16 = repro::train_cached(&ctx, &cfg16, &tc)?;

    println!("\nloss curve (10-step means):");
    for (i, chunk) in r8.losses.chunks(10).enumerate() {
        let m: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  step {:>4}  {m:.4}", i * 10);
    }
    println!(
        "\nfinal: FP8 {:.4} vs BF16 {:.4}  (low-precision convergence error {:+.3}%)",
        r8.final_loss,
        r16.final_loss,
        (r8.final_loss - r16.final_loss) / r16.final_loss * 100.0
    );
    println!("throughput: {:.0} tok/s on this host", r8.tokens_per_sec);

    // the trained FP8 weights are immediately servable in FP8 (paper §1:
    // training-inference precision match) — run the eval suite
    let ev = evaluate(ctx.backend(), &cfg8, state8.params(), tau, &corpus_for(&cfg8), 3, 7)?;
    println!(
        "\neval (FP8 W8A8-analog): next-tok {:.1}% | NLL {:.3} | cloze {:.1}% | repeat {:.1}% | induction {:.1}%",
        ev.next_token_acc * 100.0,
        ev.avg_nll,
        ev.bigram_cloze_acc * 100.0,
        ev.repeat_acc * 100.0,
        ev.induction_acc * 100.0
    );
    assert!(!r8.diverged && !r16.diverged);
    Ok(())
}
