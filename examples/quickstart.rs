//! Quickstart: train a tiny µnit-Scaled FP8 model for a few steps.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Runs out of the box on the pure-Rust reference backend (software FP8
//! emulation). With `make artifacts` + `--features pjrt` the same code
//! executes the AOT-lowered JAX/Pallas graphs on the PJRT CPU client.

use munit::config::{ModelConfig, Schedule, TrainConfig};
use munit::coordinator::trainer::Trainer;
use munit::data::{Batcher, CorpusSpec};
use munit::runtime::{open_backend, Backend};
use munit::util::error::Result;

fn main() -> Result<()> {
    // 1. open the best available backend (PJRT artifacts or reference)
    let backend = open_backend("artifacts")?;
    println!("platform: {}", backend.platform());

    // 2. pick the default proxy config: µS, FP8, width 64, 4 layers
    let cfg = ModelConfig::default();
    println!("model: {} ({} params)", cfg.name(), cfg.n_params());

    // 3. trainer + synthetic Zipf/Markov corpus
    let trainer = Trainer::new(backend.as_ref(), &cfg)?;
    let mut batcher = Batcher::new(
        CorpusSpec { vocab: cfg.vocab, ..Default::default() },
        /*seed=*/ 0, /*shard=*/ 0, /*n_shards=*/ 1,
        cfg.batch, cfg.seq_len,
    );

    // 4. train 40 steps with the µS base-width hyperparameters. The
    //    artifact itself applies the sqrt(d_base/d) transfer rule. State
    //    stays device-resident: each step moves only tokens + scalars.
    let tc = TrainConfig {
        steps: 40,
        lr: 1.0 / 64.0,  // eta at d_base = 32
        wd: 2.0 / 16384.0,
        tau: 0.4,        // fixed residual coefficient for 4 layers
        schedule: Schedule::Cosine { final_frac: 0.1, warmup: 4 },
        ..Default::default()
    };
    let r = trainer.run_with(&tc, &mut batcher, |m, _| {
        if m.step % 5 == 0 {
            println!("step {:>3}  loss {:.4}  gnorm {:.3}  lr {:.5}", m.step, m.loss, m.gnorm, m.lr);
        }
    })?;

    println!(
        "\nfinal loss {:.4} (from ln|V| = {:.3}), {:.0} tokens/s, spikes={}",
        r.final_loss(5),
        (cfg.vocab as f64).ln(),
        r.tokens_per_sec,
        r.spikes
    );
    assert!(!r.diverged, "µS FP8 training should be stable out of the box");
    Ok(())
}
