//! Attention-variance study (paper §2.1, Prop. 2.1, Figs 2-3) — pure rust
//! Monte Carlo over iid inputs plus the Pallas attention kernel round-trip.
//!
//! ```sh
//! cargo run --release --example attention_variance
//! ```

use munit::analysis::{
    attention_sigma2_theory, attention_sigma_iid, iid_cosine_baseline, AttentionKind,
};
use munit::runtime::{open_backend, tensor_f32, to_f32_vec, Backend};
use munit::util::error::Result;
use munit::util::rng::Rng;
use munit::util::stats;

fn main() -> Result<()> {
    let mut rng = Rng::new(7);
    let positions = [2usize, 4, 8, 16, 32, 64, 128, 256, 512, 1024];

    println!("sigma of attention outputs, iid N(0,1) logits and values (Fig 2):");
    println!("{:>6} {:>12} {:>12} {:>12}", "pos k", "standard", "theory", "sqrt-softmax");
    let std_curve = attention_sigma_iid(&positions, 16, 300, AttentionKind::Standard, &mut rng);
    let sqrt_curve =
        attention_sigma_iid(&positions, 16, 300, AttentionKind::SqrtSoftmax, &mut rng);
    for ((k, s_std), (_, s_sqrt)) in std_curve.iter().zip(&sqrt_curve) {
        println!(
            "{:>6} {:>12.4} {:>12.4} {:>12.4}",
            k,
            s_std,
            attention_sigma2_theory(*k).sqrt(),
            s_sqrt
        );
    }
    println!("\nstandard attention σ ~ sqrt(e/k) (Prop. 2.1); sqrt-softmax σ ≈ 1 (Eq. 8).");
    println!("iid |cos| baseline at d=16 (Fig 3): {:.4}", iid_cosine_baseline(16));

    // Cross-check through the Pallas kernel artifact, if available: the
    // kernels_demo artifact only exists in the AOT catalogue (pjrt build).
    let backend = open_backend("artifacts")?;
    if backend.manifest().find("kernels_demo").is_some() {
        let (bh, s, dh) = (2usize, 64usize, 16usize);
        let mut fill = |n: usize| {
            let mut v = vec![0f32; n];
            rng.fill_normal(&mut v, 1.0);
            v
        };
        let x = tensor_f32(&fill(64 * 32), &[64, 32])?;
        let g = tensor_f32(&vec![1.0; 32], &[32])?;
        let b = tensor_f32(&vec![0.0; 32], &[32])?;
        let mk = |v: &[f32]| tensor_f32(v, &[bh, s, dh]);
        // scale q,k so logits are ~N(0,1) like the simulation
        let scale = (dh as f32).powf(-0.25);
        let q: Vec<f32> = fill(bh * s * dh).iter().map(|v| v * scale).collect();
        let k: Vec<f32> = fill(bh * s * dh).iter().map(|v| v * scale).collect();
        let v = fill(bh * s * dh);
        let outs = backend.run("kernels_demo", &[x, g, b, mk(&q)?, mk(&k)?, mk(&v)?])?;
        let a_std = to_f32_vec(&outs[3])?;
        let a_sqrt = to_f32_vec(&outs[4])?;
        let pos_std = |out: &[f32], pos: usize| {
            let mut vals = Vec::new();
            for head in 0..bh {
                let o = (head * s + pos) * dh;
                vals.extend_from_slice(&out[o..o + dh]);
            }
            stats::std(&vals)
        };
        println!("\nthrough the Pallas kernel (seq 64, via the rust/PJRT bridge):");
        for pos in [4usize, 16, 63] {
            println!(
                "  pos {:>2}: standard σ {:.3}  sqrt σ {:.3}",
                pos,
                pos_std(&a_std, pos),
                pos_std(&a_sqrt, pos)
            );
        }
    } else {
        println!("\n(no kernels_demo artifact on this backend; skipping the Pallas cross-check)");
    }
    Ok(())
}
