//! Simulated multi-worker data-parallel training (DESIGN.md substitution
//! for the paper's 64-GPU runs): k workers, disjoint corpus shards,
//! lockstep steps, parameter-mean allreduce — with NO per-tensor amax
//! exchange, the distributed-training simplification µS buys (§3.3).
//!
//! Each worker owns a device-resident Session; the allreduce is the only
//! full-state host transfer per step (the collective boundary).
//!
//! ```sh
//! cargo run --release --example ddp_train -- [workers] [steps]
//! ```

use munit::config::ModelConfig;
use munit::coordinator::ddp::train_ddp;
use munit::data::CorpusSpec;
use munit::repro::proxy_tc;
use munit::runtime::open_backend;
use munit::util::error::Result;

fn main() -> Result<()> {
    let workers: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let steps: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(20);
    let backend = open_backend("artifacts")?;
    let cfg = ModelConfig::default();
    let tc = proxy_tc(steps, 1.0 / 64.0, 2.0 / 16384.0, 0.4, 0);

    println!("simulated DDP: {workers} workers x {} tokens/step", cfg.batch * cfg.seq_len);
    let r = train_ddp(backend.as_ref(), &cfg, &tc, &CorpusSpec::default(), workers)?;
    for (i, loss) in r.losses.iter().enumerate() {
        if i % 5 == 0 {
            println!("  step {i:>3}  mean worker loss {loss:.4}");
        }
    }
    println!(
        "final loss {:.4}, aggregate {:.0} tok/s, diverged={}",
        r.final_loss(5),
        r.tokens_per_sec,
        r.diverged
    );
    Ok(())
}
