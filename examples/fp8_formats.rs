//! FP8 number-format exploration (paper App. A.5 / Fig 10).
//!
//! Prints the e4m3/e5m2/bf16 format properties the µS design rests on and
//! the activation-function underflow study, all on the software FP8
//! substrate (bit-exact vs ml_dtypes — see artifacts/goldens.json tests).
//!
//! ```sh
//! cargo run --release --example fp8_formats
//! ```

use munit::analysis::{activation_underflow, activations::Activation, InputDist};
use munit::fp8::{BF16, E4M3, E5M2};
use munit::util::rng::Rng;

fn main() {
    println!("format properties:");
    println!(
        "{:>6} {:>12} {:>14} {:>14} {:>10} {:>8}",
        "fmt", "max", "min normal", "min subnormal", "eps@1", "values"
    );
    for fmt in [E4M3, E5M2, BF16] {
        println!(
            "{:>6} {:>12.4e} {:>14.4e} {:>14.4e} {:>10.4e} {:>8}",
            fmt.name,
            fmt.max_finite(),
            fmt.min_normal(),
            fmt.min_subnormal(),
            fmt.epsilon(),
            fmt.finite_value_count()
        );
    }

    println!("\nwhy µS clips before casting (e4m3fn overflows to NaN):");
    for v in [447.0f32, 448.0, 449.0, 465.0, 1000.0] {
        println!("  raw cast({v:>7}) = {:>7}   quantize({v:>7}) = {:>7}",
            E4M3.cast(v), E4M3.quantize(v));
    }

    println!("\nunit-variance tensors survive the static cast; badly scaled ones die:");
    let mut rng = Rng::new(1);
    for scale in [1.0f32, 1e-3, 1e-6] {
        let mut xs = vec![0f32; 10_000];
        rng.fill_normal(&mut xs, scale);
        println!(
            "  N(0, {scale:>5.0e}):  e4m3 underflow {:>8.4}%",
            E4M3.underflow_fraction(&xs) * 100.0
        );
    }

    println!("\nactivation-function output underflow (Fig 10), 400k samples:");
    println!("{:>6} {:>16} {:>20}", "act", "N(0,1)", "Unif(-128,128)");
    for act in Activation::all() {
        let n = activation_underflow(act, InputDist::StdNormal, E4M3, 400_000, &mut rng);
        let u = activation_underflow(act, InputDist::Uniform128, E4M3, 400_000, &mut rng);
        println!("{:>6} {:>15.4}% {:>19.4}%", act.name(), n * 100.0, u * 100.0);
    }
    println!("\nReLU ≈ 0 underflow; SiLU worst over wide ranges (paper App. A.5).");
}
