//! Zero-shot hyperparameter transfer demo (paper §2.3 / Fig 6, miniature).
//!
//! Sweeps the base-width learning rate η over powers of two at two widths
//! (32 = d_base, and 128 = 4x wider) for µnit-Scaled FP8 models. Because
//! the backends bake the √(d_base/d) hidden-layer rule, the optimal
//! *base* η should be (nearly) the same at both widths — that is zero-shot
//! transfer. The sweep runs as in-process worker threads over the shared
//! thread-safe backend.
//!
//! ```sh
//! cargo run --release --example hp_transfer
//! ```

use munit::config::ModelConfig;
use munit::coordinator::sweep;
use munit::data::CorpusSpec;
use munit::repro::proxy_tc;
use munit::runtime::open_backend;
use munit::util::error::Result;

fn main() -> Result<()> {
    let backend = open_backend("artifacts")?;
    let corpus = CorpusSpec::default();
    let lrs = sweep::pow2_axis(-8, -4);
    let steps = 40;

    for width in [32usize, 128] {
        let cfg = ModelConfig { width, ..ModelConfig::default() };
        println!("\nwidth {width} (mult on hidden LR: sqrt(32/{width}) = {:.3}):",
            (32.0 / width as f64).sqrt());
        let points = sweep::grid(&lrs, &[2.0 / 16384.0], &[0.4]);
        // 2 worker threads over the shared backend
        let outcomes = sweep::run_parallel(
            backend.as_ref(),
            &cfg,
            &proxy_tc(steps, 0.0, 0.0, 0.4, 6),
            &corpus,
            &points,
            2,
            false,
        )?;
        for o in &outcomes {
            println!(
                "  eta_base = 2^{:>3.0}  ->  loss {:.4}{}",
                o.point.lr.log2(),
                o.final_loss,
                if o.diverged { "  DIVERGED" } else { "" }
            );
        }
        let best = sweep::best(&outcomes).expect("all diverged");
        println!("  η* (base units) = 2^{:.0}", best.point.lr.log2());
    }
    println!("\nExpect: the two η* rows agree (µS transfer), unlike SP where");
    println!("the optimum would shift by ~the width ratio.");
    Ok(())
}
