"""AOT export path: HLO text generation + manifest consistency."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.configs import ModelConfig, param_specs


def test_to_hlo_text_contains_fp8_types(tmp_path):
    cfg = ModelConfig(width=32, depth=2, head_dim=16, vocab=64, seq_len=32,
                      batch=2, d_base=32, variant="mus", precision="fp8")
    params, mom = model.init_state(0, cfg)
    tokens = jnp.zeros((2, 32), jnp.int32)

    def f(*args):
        n = len(params)
        p, m = list(args[:n]), list(args[n:2 * n])
        t, lr, wd, tau = args[2 * n:]
        p2, m2, loss, g = model.train_step(p, m, t, lr, wd, tau, cfg)
        return tuple(p2) + tuple(m2) + (loss, g)

    lowered = jax.jit(f, keep_unused=True).lower(
        *params, *mom, tokens, 0.001, 0.0001, 0.3
    )
    text = aot.to_hlo_text(lowered)
    assert "f8e4m3" in text        # forward quantization present
    assert "f8e5m2" in text        # gradient quantization present
    assert "ENTRY" in text         # parseable HLO text module


def test_builder_writes_manifest_and_skips_existing(tmp_path):
    b = aot.Builder(str(tmp_path))
    b.add("t1", "demo", lambda x: (x + 1.0,),
          [aot._spec("x", (2, 2))], [aot._spec("y", (2, 2))])
    assert os.path.exists(tmp_path / "t1.hlo.txt")
    sz = os.path.getsize(tmp_path / "t1.hlo.txt")
    # duplicate name: ignored entirely
    b.add("t1", "demo", lambda x: (x + 2.0,),
          [aot._spec("x", (2, 2))], [aot._spec("y", (2, 2))])
    assert len(b.entries) == 1
    b.write_manifest()
    m = json.loads((tmp_path / "manifest.json").read_text())
    assert m["artifacts"][0]["name"] == "t1"
    assert m["artifacts"][0]["inputs"][0]["shape"] == [2, 2]
    assert os.path.getsize(tmp_path / "t1.hlo.txt") == sz


def test_repo_manifest_matches_param_specs():
    """The shipped manifest's train artifacts agree with param_specs."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    m = json.loads(open(path).read())
    trains = [a for a in m["artifacts"] if a["kind"] == "train_step"]
    assert trains, "no train artifacts"
    for a in trains[:6]:
        c = a["config"]
        cfg = ModelConfig(
            width=c["width"], depth=c["depth"], head_dim=c["head_dim"],
            vocab=c["vocab"], seq_len=c["seq_len"], batch=c["batch"],
            d_base=c["d_base"], variant=c["variant"], precision=c["precision"],
            residual=c["residual"], activation=c["activation"],
        )
        specs = param_specs(cfg)
        n = len(specs)
        assert len(a["inputs"]) == 2 * n + 4
        assert len(a["outputs"]) == 2 * n + 2
        for (name, shape), inp in zip(specs, a["inputs"][:n]):
            assert inp["name"] == name
            assert tuple(inp["shape"]) == tuple(shape)


def test_goldens_roundtrip(tmp_path):
    aot.write_goldens(str(tmp_path))
    g = json.loads((tmp_path / "goldens.json").read_text())
    assert len(g["input"]) == len(g["e4m3_static"]) == len(g["bf16"])
    i = g["input"].index(449.0)
    assert g["e4m3_static"][i] == 448.0   # clipped then exact
    i = g["input"].index(1e-9)
    assert g["e4m3_raw"][i] == 0.0        # deep underflow
