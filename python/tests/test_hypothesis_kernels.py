"""Hypothesis sweeps over kernel shapes/formats: Pallas vs ref oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention as pallas_attention
from compile.kernels.cast_transpose import cast_transpose as pallas_ct
from compile.kernels.fp8_matmul import scaled_matmul
from compile.kernels.layernorm import layernorm as pallas_ln

FMTS = st.sampled_from(["none", "bf16", "e4m3", "e5m2"])
SETTINGS = dict(max_examples=12, deadline=None)


def _arr(seed, shape, scale):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([4, 8, 24]),
    k=st.sampled_from([4, 16, 32]),
    n=st.sampled_from([4, 8, 40]),
    xf=FMTS,
    wf=FMTS,
    scale=st.sampled_from([0.01, 1.0, 300.0]),
    seed=st.integers(0, 2**16),
)
def test_scaled_matmul_any_shape_fmt(m, k, n, xf, wf, scale, seed):
    x = _arr(seed, (m, k), scale)
    w = _arr(seed + 1, (k, n), 1.0)
    got = scaled_matmul(x, w, 1.0 / k**0.5, xf, wf)
    want = ref.scaled_matmul(x, w, 1.0 / k**0.5, xf, wf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([8, 16, 64]),
    n=st.sampled_from([8, 32]),
    fmt=st.sampled_from(["e4m3", "e5m2"]),
    block=st.sampled_from([None, 8]),
    scale=st.sampled_from([0.001, 1.0, 5000.0]),
    seed=st.integers(0, 2**16),
)
def test_cast_transpose_any(m, n, fmt, block, scale, seed):
    x = _arr(seed, (m, n), scale)
    q, qt = pallas_ct(x, fmt, block=block)
    rq, rqt = ref.cast_transpose(x, fmt)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(rq))
    np.testing.assert_array_equal(np.asarray(qt), np.asarray(rqt))


@settings(**SETTINGS)
@given(
    r=st.sampled_from([4, 16, 32]),
    d=st.sampled_from([8, 48]),
    scale=st.sampled_from([0.1, 1.0, 100.0]),
    seed=st.integers(0, 2**16),
)
def test_layernorm_any(r, d, scale, seed):
    x = _arr(seed, (r, d), scale)
    g = 1.0 + 0.1 * _arr(seed + 1, (d,), 1.0)
    b = _arr(seed + 2, (d,), 0.5)
    got = pallas_ln(x, g, b)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 4]),
    s=st.sampled_from([8, 32]),
    dh=st.sampled_from([8, 16]),
    sqrt_softmax=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_attention_any(b, h, s, dh, sqrt_softmax, seed):
    q = _arr(seed, (b, h, s, dh), 1.0)
    k = _arr(seed + 1, (b, h, s, dh), 1.0)
    v = _arr(seed + 2, (b, h, s, dh), 1.0)
    got = pallas_attention(q, k, v, sqrt_softmax=sqrt_softmax)
    want = ref.attention(q, k, v, sqrt_softmax=sqrt_softmax)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
