"""Lion optimizer reference semantics (paper App. A.3)."""

import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import lion_update


def test_lion_sign_update():
    p = jnp.zeros((4,))
    m = jnp.zeros((4,))
    g = jnp.array([3.0, -0.5, 0.0, 100.0])
    p2, m2 = lion_update(p, m, g, lr=0.1, wd=0.0)
    np.testing.assert_allclose(np.asarray(p2), [-0.1, 0.1, 0.0, -0.1])
    np.testing.assert_allclose(np.asarray(m2), 0.01 * np.asarray(g), rtol=1e-6)


def test_lion_momentum_interpolation():
    """Update direction uses beta1 (0.9) interpolation; momentum uses beta2."""
    p = jnp.zeros((1,))
    m = jnp.array([1.0])
    g = jnp.array([-5.0])
    # c = 0.9*1 + 0.1*(-5) = 0.4 > 0  -> step is -lr
    p2, m2 = lion_update(p, m, g, lr=0.5, wd=0.0)
    assert float(p2[0]) == -0.5
    np.testing.assert_allclose(float(m2[0]), 0.99 * 1.0 + 0.01 * (-5.0), rtol=1e-6)


def test_fully_decoupled_weight_decay():
    """wd is NOT multiplied by lr (Wortsman et al. 2024 formulation)."""
    p = jnp.array([2.0])
    m = jnp.zeros((1,))
    g = jnp.zeros((1,))
    p2, _ = lion_update(p, m, g, lr=0.0, wd=0.25)
    assert float(p2[0]) == 1.5  # 2.0 - 0.25*2.0, independent of lr=0


def test_update_magnitude_independent_of_grad_scale():
    """Sign-based update: scaling the gradient leaves the step unchanged —
    why µP's Adam-like rules apply to Lion."""
    p = jnp.zeros((8,))
    m = jnp.zeros((8,))
    g = jnp.linspace(-1, 1, 8)
    p_a, _ = lion_update(p, m, g, lr=0.1, wd=0.0)
    p_b, _ = lion_update(p, m, 1000.0 * g, lr=0.1, wd=0.0)
    np.testing.assert_array_equal(np.asarray(p_a), np.asarray(p_b))
