"""L2 model invariants: init variance, residual-stream scale, loss sanity,
training progress, transfer multipliers, residual schemes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ModelConfig, lr_mult, output_mult, param_specs, wd_mult

TINY = dict(width=32, depth=2, head_dim=16, vocab=64, seq_len=32, batch=2, d_base=32)


def cfg_of(**kw):
    base = dict(TINY)
    base.update(kw)
    return ModelConfig(**base)


def tokens_for(cfg, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (cfg.batch, cfg.seq_len), 0, cfg.vocab)


class TestInit:
    def test_mus_unit_variance(self):
        cfg = cfg_of(width=64, variant="mus")
        params = model.init_params(0, cfg)
        names = [n for n, _ in param_specs(cfg)]
        for n, p in zip(names, params):
            if n.startswith("rms"):
                continue
            assert abs(float(jnp.std(p)) - 1.0) < 0.05, n

    def test_sp_sigma_init(self):
        cfg = cfg_of(width=64, variant="sp", residual="standard", sigma_init=0.02)
        params = model.init_params(0, cfg)
        names = [n for n, _ in param_specs(cfg)]
        for n, p in zip(names, params):
            if n.startswith("rms"):
                continue
            assert abs(float(jnp.std(p)) - 0.02) < 0.005, n

    def test_rms_gain_init(self):
        cfg = cfg_of()
        params = model.init_params(0, cfg)
        d = dict(zip([n for n, _ in param_specs(cfg)], params))
        assert float(jnp.min(d["rms1_g"])) == 1.0
        assert float(jnp.max(d["rms1_g"])) == 1.0
        assert float(jnp.min(d["rmsf_g"])) == 1.0

    def test_momentum_zero(self):
        cfg = cfg_of()
        _, mom = model.init_state(0, cfg)
        assert all(float(jnp.max(jnp.abs(m))) == 0.0 for m in mom)

    def test_seeds_differ(self):
        cfg = cfg_of()
        p0 = model.init_params(0, cfg)
        p1 = model.init_params(1, cfg)
        assert float(jnp.max(jnp.abs(p0[0] - p1[0]))) > 0.0


class TestForward:
    @pytest.mark.parametrize("variant,precision", [("mus", "fp8"), ("mus", "bf16"),
                                                   ("sp", "fp8"), ("sp", "bf16")])
    def test_shapes_and_finite(self, variant, precision):
        res = "fixed" if variant == "mus" else "standard"
        cfg = cfg_of(variant=variant, precision=precision, residual=res)
        params = model.init_params(0, cfg)
        logits = model.forward(params, tokens_for(cfg), 0.3, cfg)
        assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_loss_near_uniform_at_init(self):
        cfg = cfg_of()
        params = model.init_params(0, cfg)
        loss = model.loss_fn(params, tokens_for(cfg), 0.3, cfg)
        assert abs(float(loss) - np.log(cfg.vocab)) < 0.5

    def test_mus_residual_stream_unit_scale_in_depth(self):
        """Res-Post-LN + fixed(tau) keeps the stream near unit std at every
        depth (the property that makes static FP8 casting viable)."""
        cfg = cfg_of(width=64, depth=8)
        params = model.init_params(0, cfg)
        _, stats = model.forward(params, tokens_for(cfg), 0.3, cfg, probe=True)
        resid_std = np.asarray(stats.resid_std)  # [L, S]
        per_layer = resid_std.mean(axis=1)
        assert np.all(per_layer > 0.7) and np.all(per_layer < 1.3), per_layer

    def test_causality_of_full_model(self):
        cfg = cfg_of()
        params = model.init_params(0, cfg)
        t = tokens_for(cfg)
        base = model.forward(params, t, 0.3, cfg)
        t2 = t.at[:, -1].set((t[:, -1] + 7) % cfg.vocab)
        pert = model.forward(params, t2, 0.3, cfg)
        np.testing.assert_allclose(
            np.asarray(base[:, :-1]), np.asarray(pert[:, :-1]), rtol=1e-4, atol=1e-5
        )


class TestResidualSchemes:
    def test_fixed_coeffs(self):
        cfg = cfg_of(residual="fixed", depth=3)
        c = np.asarray(model._residual_coeffs(0.19, cfg))
        assert c.shape == (3, 2, 2)
        np.testing.assert_allclose(c[..., 0], np.sqrt(1 - 0.19), rtol=1e-6)
        np.testing.assert_allclose(c[..., 1], np.sqrt(0.19), rtol=1e-6)

    def test_fixed_variance_preserving(self):
        c = np.asarray(model._residual_coeffs(0.4, cfg_of(residual="fixed")))
        np.testing.assert_allclose(c[..., 0] ** 2 + c[..., 1] ** 2, 1.0, rtol=1e-6)

    def test_running_mean_variance_preserving(self):
        c = np.asarray(model._residual_coeffs(0.0, cfg_of(residual="running_mean", depth=5)))
        np.testing.assert_allclose(c[..., 0] ** 2 + c[..., 1] ** 2, 1.0, rtol=1e-6)
        # branch weights decay with depth (Eq. 11)
        assert c[0, 0, 1] > c[4, 1, 1]

    def test_standard_coeffs_all_ones(self):
        c = np.asarray(model._residual_coeffs(0.3, cfg_of(residual="standard")))
        np.testing.assert_array_equal(c, np.ones_like(c))


class TestTrainStep:
    @pytest.mark.parametrize("variant,precision", [("mus", "fp8"), ("sp", "bf16")])
    def test_loss_decreases(self, variant, precision):
        res = "fixed" if variant == "mus" else "standard"
        cfg = cfg_of(variant=variant, precision=precision, residual=res)
        params, mom = model.init_state(0, cfg)
        step = jax.jit(lambda p, m, t: model.train_step(p, m, t, 2**-7, 1e-4, 0.4, cfg))
        losses = []
        t = tokens_for(cfg)  # overfit one batch
        for _ in range(12):
            params, mom, loss, gnorm = step(params, mom, t)
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.2, losses
        assert np.isfinite(losses).all()

    def test_gnorm_positive_finite(self):
        cfg = cfg_of()
        params, mom = model.init_state(0, cfg)
        *_, gnorm = model.train_step(params, mom, tokens_for(cfg), 1e-3, 0.0, 0.3, cfg)
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    def test_wd_shrinks_weights_not_norm_gains(self):
        cfg = cfg_of()
        params, mom = model.init_state(0, cfg)
        names = [n for n, _ in param_specs(cfg)]
        p2, *_ = model.train_step(params, mom, tokens_for(cfg), 0.0, 0.1, 0.3, cfg)
        d0 = dict(zip(names, params))
        d1 = dict(zip(names, p2))
        # lr=0: only fully-decoupled wd acts -> decayed params shrink by 0.9
        np.testing.assert_allclose(np.asarray(d1["w_o"]), 0.9 * np.asarray(d0["w_o"]), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(d1["rms1_g"]), np.asarray(d0["rms1_g"]))


class TestTransferRules:
    def test_mus_hidden_lr_sqrt_rule(self):
        cfg = cfg_of(width=128, d_base=32)
        assert lr_mult(cfg, "w_qkv") == pytest.approx(0.5)  # sqrt(32/128)
        assert lr_mult(cfg, "embed") == 1.0
        assert lr_mult(cfg, "head") == 1.0
        assert lr_mult(cfg, "rms1_g") == 1.0

    def test_sp_linear_lr_rule(self):
        cfg = cfg_of(width=128, d_base=32, variant="sp", residual="standard")
        assert lr_mult(cfg, "w_qkv") == pytest.approx(0.25)  # 32/128
        assert lr_mult(cfg, "embed") == pytest.approx(0.25)

    def test_output_multipliers_table2(self):
        cfg = cfg_of(width=64)
        assert output_mult(cfg, "w_qkv") == pytest.approx(64**-0.5)
        assert output_mult(cfg, "w_down") == pytest.approx((64 * 4) ** -0.5)
        assert output_mult(cfg, "head") == pytest.approx(1 / 64)
        assert output_mult(cfg, "embed") == 1.0

    def test_wd_applies_to_matrices_only(self):
        cfg = cfg_of()
        assert wd_mult(cfg, "w_up") == 1.0
        assert wd_mult(cfg, "embed") == 1.0
        assert wd_mult(cfg, "rms2_g") == 0.0
        assert wd_mult(cfg, "rmsf_g") == 0.0


class TestMuPInvariance:
    def test_abc_rescale_invariance_under_lion(self):
        """Yang et al. Eq. 15 specialization the µS derivation rests on:
        (a,b,c) -> (a*t, b/t, c/t) leaves the layer's training trajectory
        outputs invariant under sign-based (Adam-like) optimizers."""
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (16, 8))
        w0 = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
        tgt = jax.random.normal(jax.random.PRNGKey(2), (16, 8))

        def train(a, w, c, steps=5):
            m = jnp.zeros_like(w)
            outs = []
            for _ in range(steps):
                def loss(w):
                    return jnp.mean((a * x @ w - tgt) ** 2)
                g = jax.grad(loss)(w)
                cmb = 0.9 * m + 0.1 * g
                w = w - c * jnp.sign(cmb)
                m = 0.99 * m + 0.01 * g
                outs.append(a * x @ w)
            return outs

        t = 4.0
        o1 = train(1.0, w0, 1e-2)
        o2 = train(1.0 * t, w0 / t, 1e-2 / t)
        for u, v in zip(o1, o2):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v), rtol=1e-5, atol=1e-6)
