"""Quantization primitive semantics (static µS casts vs dynamic TE casts)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import FP8_E4M3_MAX, FP8_E5M2_MAX
from compile.kernels.fp8 import (
    dynamic_scale,
    quantize,
    quantize_dynamic,
    underflow_fraction,
)


@pytest.mark.parametrize("fmt,fmax", [("e4m3", FP8_E4M3_MAX), ("e5m2", FP8_E5M2_MAX)])
class TestStaticQuantize:
    def test_idempotent(self, fmt, fmax):
        x = jnp.linspace(-500.0, 500.0, 257)
        q = quantize(x, fmt)
        np.testing.assert_array_equal(quantize(q, fmt), q)

    def test_saturates_at_max(self, fmt, fmax):
        x = jnp.array([fmax, fmax * 2, 1e30, -1e30])
        q = quantize(x, fmt)
        np.testing.assert_array_equal(q, jnp.array([fmax, fmax, fmax, -fmax]))

    def test_odd_symmetry(self, fmt, fmax):
        x = jnp.linspace(0.0, 2 * fmax, 101)
        np.testing.assert_array_equal(quantize(-x, fmt), -quantize(x, fmt))

    def test_monotone(self, fmt, fmax):
        x = jnp.sort(jnp.linspace(-2 * fmax, 2 * fmax, 513))
        q = quantize(x, fmt)
        assert bool(jnp.all(jnp.diff(q) >= 0))

    def test_exact_on_representable(self, fmt, fmax):
        # powers of two well inside range are exactly representable
        x = jnp.array([2.0**e for e in range(-6, 8)])
        np.testing.assert_array_equal(quantize(x, fmt), x)


def test_e4m3_resolution_coarser_than_e5m2_range():
    # e4m3: more mantissa (finer around 1.0); e5m2: more range.
    x = jnp.array([1.0 + 1.0 / 8.0])  # representable in e4m3 (3 mantissa bits), not e5m2
    assert float(quantize(x, "e4m3")[0]) == float(x[0])
    assert float(quantize(x, "e5m2")[0]) != float(x[0])
    big = jnp.array([30000.0])
    assert float(quantize(big, "e5m2")[0]) == pytest.approx(30000.0, rel=0.25)
    assert float(quantize(big, "e4m3")[0]) == FP8_E4M3_MAX  # saturated


def test_bf16_roundtrip():
    x = jnp.array([1.0, 1.0 + 2**-8, 3.0e38])
    q = quantize(x, "bf16")
    assert float(q[0]) == 1.0
    assert float(q[1]) in (1.0, float(1.0 + 2**-8))
    assert np.isfinite(float(q[2]))


def test_dynamic_scale_fills_range():
    x = jnp.array([0.001, -0.002, 0.0005])
    s = float(dynamic_scale(x, "e4m3"))
    assert s == pytest.approx(FP8_E4M3_MAX / 0.002, rel=1e-5)
    q, s2 = quantize_dynamic(x, "e4m3")
    assert float(jnp.max(jnp.abs(q))) <= FP8_E4M3_MAX
    # rescaled values recover the original within e4m3 relative error
    np.testing.assert_allclose(np.asarray(q) / s2, np.asarray(x), rtol=0.07)


def test_underflow_fraction_bounds():
    # values far below e4m3 min subnormal (2^-9) all underflow
    tiny = jnp.full((64,), 1e-6)
    assert float(underflow_fraction(tiny, "e4m3")) == 1.0
    ok = jnp.full((64,), 1.0)
    assert float(underflow_fraction(ok, "e4m3")) == 0.0
    zeros = jnp.zeros((64,))
    assert float(underflow_fraction(zeros, "e4m3")) == 0.0  # 0s don't count
