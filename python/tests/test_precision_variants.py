"""Cross-precision behavior of the four (variant, precision) model
families — the properties Figs 6/7 rely on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ModelConfig


def cfg_of(**kw):
    base = dict(width=32, depth=2, head_dim=16, vocab=64, seq_len=32, batch=2, d_base=32)
    base.update(kw)
    return ModelConfig(**base)


def tokens_for(cfg, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (cfg.batch, cfg.seq_len), 0, cfg.vocab)


def test_mus_fp8_close_to_bf16_at_init():
    """Static FP8 casting on unit-variance tensors is a small perturbation:
    the FP8 and BF16 µS models should produce nearby losses at init."""
    c8 = cfg_of(precision="fp8")
    c16 = cfg_of(precision="bf16")
    params = model.init_params(0, c8)
    t = tokens_for(c8)
    l8 = float(model.loss_fn(params, t, 0.3, c8))
    l16 = float(model.loss_fn(params, t, 0.3, c16))
    assert abs(l8 - l16) < 0.05, (l8, l16)


def test_sp_fp8_dynamic_close_to_bf16_at_init():
    """TE-style dynamic scaling rescues SP's small-sigma tensors."""
    c8 = cfg_of(variant="sp", precision="fp8", residual="standard")
    c16 = cfg_of(variant="sp", precision="bf16", residual="standard")
    params = model.init_params(0, c8)
    t = tokens_for(c8)
    l8 = float(model.loss_fn(params, t, 0.0, c8))
    l16 = float(model.loss_fn(params, t, 0.0, c16))
    assert abs(l8 - l16) < 0.05, (l8, l16)


def test_sp_static_fp8_would_collapse():
    """Why SP needs dynamic scaling: statically casting sigma=0.02 weights
    to e4m3 flushes most mass (resolution near 0.02 is coarse relative to
    the weights' scale... actually: 0.02-scale values survive e4m3, but the
    *products* (0.02 * 0.02 * fan_in) vanish through layers). We check the
    narrower, always-true statement: µS unit-variance tensors suffer ~0
    quantization-induced loss shift while a 1e-5-scaled tensor is erased."""
    from compile.kernels.fp8 import quantize

    x = 1e-5 * jax.random.normal(jax.random.PRNGKey(0), (1024,))
    assert float(jnp.sum(jnp.abs(quantize(x, "e4m3")))) == 0.0
    u = jax.random.normal(jax.random.PRNGKey(1), (1024,))
    q = quantize(u, "e4m3")
    rel = float(jnp.linalg.norm(q - u) / jnp.linalg.norm(u))
    assert rel < 0.06, rel  # ~2^-4 worst-case relative error, ~4% RMS


@pytest.mark.parametrize("residual", ["fixed", "running_mean"])
def test_residual_schemes_train(residual):
    cfg = cfg_of(residual=residual, depth=4)
    params, mom = model.init_state(0, cfg)
    t = tokens_for(cfg)
    step = jax.jit(lambda p, m: model.train_step(p, m, t, 2**-7, 0.0, 0.2, cfg))
    for _ in range(8):
        params, mom, loss, _ = step(params, mom)
    assert np.isfinite(float(loss))


def test_unit_variance_activations_across_widths():
    """The enabler of static FP8: at init, µS keeps the residual stream at
    unit scale regardless of width (so e4m3's range always fits)."""
    for w in [32, 64, 128]:
        cfg = cfg_of(width=w, depth=3)
        params = model.init_params(0, cfg)
        _, stats = model.forward(params, tokens_for(cfg), 0.3, cfg, probe=True)
        per_layer = np.asarray(stats.resid_std).mean(axis=1)
        assert np.all(per_layer > 0.7) and np.all(per_layer < 1.3), (w, per_layer)


def test_sp_residual_stream_grows_with_depth():
    """Contrast: SP's pre-LN summation grows the stream like sqrt(depth) —
    the mechanism behind Fig 12's outliers."""
    cfg = cfg_of(variant="sp", residual="standard", depth=6, sigma_init=0.08)
    params = model.init_params(0, cfg)
    _, stats = model.forward(params, tokens_for(cfg), 0.0, cfg, probe=True)
    per_layer = np.asarray(stats.resid_std).mean(axis=1)
    assert per_layer[-1] > per_layer[0], per_layer


def test_width_changes_only_hidden_lr():
    """Transfer rule sanity at the train_step level: with lr=0 nothing
    moves; with wd=0,lr>0 hidden updates shrink by sqrt(d_base/width)."""
    from compile.configs import param_specs

    for w, expected in [(32, 1.0), (128, 0.5)]:
        cfg = cfg_of(width=w, depth=2)
        params, mom = model.init_state(0, cfg)
        t = tokens_for(cfg)
        p2, *_ = model.train_step(params, mom, t, 1e-2, 0.0, 0.3, cfg)
        names = [n for n, _ in param_specs(cfg)]
        d = dict(zip(names, params))
        d2 = dict(zip(names, p2))
        # Lion: |update| = lr * mult exactly (sign update, wd=0)
        delta = np.abs(np.asarray(d2["w_o"]) - np.asarray(d["w_o"]))
        np.testing.assert_allclose(delta.max(), 1e-2 * expected, rtol=1e-4)
        delta_e = np.abs(np.asarray(d2["embed"]) - np.asarray(d["embed"]))
        # embedding LR never scales; most rows untouched (gather), so max
        np.testing.assert_allclose(delta_e.max(), 1e-2, rtol=1e-4)
