"""Probe graph invariants (backing Figs 2, 3, 11, 12)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.configs import HIST_NBINS, ModelConfig


def _cfg(**kw):
    base = dict(width=32, depth=3, head_dim=16, vocab=64, seq_len=48, batch=2, d_base=32)
    base.update(kw)
    return ModelConfig(**base)


def _probe(cfg, seed=0):
    params = model.init_params(seed, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    return model.probe_fn(params, tokens, 0.3, cfg)


def test_probe_shapes_and_ranges():
    cfg = _cfg()
    out = _probe(cfg)
    attn_std, attn_sqrt_std, vcos, resid_std, underflow, hist_in, hist_out, loss = out
    L, S = cfg.depth, cfg.seq_len
    assert attn_std.shape == (L, S) and attn_sqrt_std.shape == (L, S)
    assert vcos.shape == (L, S) and resid_std.shape == (L, S)
    assert underflow.shape == (L, 5)
    assert hist_in.shape == (L, HIST_NBINS) and hist_out.shape == (L, HIST_NBINS)
    assert np.isfinite(float(loss))
    u = np.asarray(underflow)
    assert np.all(u >= 0) and np.all(u <= 1)
    c = np.asarray(vcos)
    assert np.all(c >= -1.001) and np.all(c <= 1.001)
    assert float(c[0, 0]) == 0.0  # position 0 has no predecessors


def test_histograms_normalized():
    out = _probe(_cfg())
    hist_in, hist_out = np.asarray(out[5]), np.asarray(out[6])
    np.testing.assert_allclose(hist_in.sum(axis=1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(hist_out.sum(axis=1), 1.0, rtol=1e-5)


def test_attn_std_decays_with_position_at_init():
    """Fig 2 (red): with near-iid values (random init), standard attention
    output std decays with sequence position; sqrt-softmax stays flat-ish."""
    cfg = _cfg(seq_len=128, width=64)
    out = _probe(cfg)
    attn_std, attn_sqrt_std = np.asarray(out[0]), np.asarray(out[1])
    early = attn_std[:, 2:8].mean()
    late = attn_std[:, -16:].mean()
    assert late < 0.75 * early, (early, late)
    early_s = attn_sqrt_std[:, 2:8].mean()
    late_s = attn_sqrt_std[:, -16:].mean()
    assert late_s > 0.6 * early_s, (early_s, late_s)


def test_relu_underflow_lower_than_gelu():
    """App. A.5: ReLU's act-output FP8 underflow is orders of magnitude
    below GELU's (exact zeros don't count as underflow)."""
    u_gelu = np.asarray(_probe(_cfg(activation="gelu"))[4])[:, 3].mean()
    u_relu = np.asarray(_probe(_cfg(activation="relu"))[4])[:, 3].mean()
    assert u_relu < 0.5 * u_gelu or u_relu == 0.0, (u_gelu, u_relu)


def test_probe_loss_matches_loss_fn():
    cfg = _cfg()
    params = model.init_params(0, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    probe_loss = float(model.probe_fn(params, tokens, 0.3, cfg)[-1])
    plain_loss = float(model.loss_fn(params, tokens, 0.3, cfg))
    assert abs(probe_loss - plain_loss) < 1e-5
