"""Structural L1 perf analysis (VMEM/MXU model) sanity checks."""

from compile.kernels.analysis import GemmTile, VMEM_BYTES, paper_scale_tiles, report


def test_paper_tiles_fit_vmem_after_auto_tiling():
    text = report(paper_scale_tiles(), "t")
    assert "NO" not in text  # every kernel's chosen block fits VMEM


def test_mxu_alignment_of_chosen_blocks():
    for t in paper_scale_tiles():
        assert t.mxu_utilization() == 1.0, t.name  # multiples of 128


def test_misaligned_block_penalized():
    t = GemmTile("odd", 100, 4096, 4096, 100)
    assert t.mxu_utilization() < 0.85


def test_paper_gemms_compute_bound():
    for t in paper_scale_tiles():
        assert t.roofline_bound() == "compute", t.name


def test_vmem_model_monotone_in_block():
    small = GemmTile("s", 8192, 4096, 1024, 256)
    large = GemmTile("l", 8192, 4096, 1024, 1024)
    assert small.vmem_bytes() < large.vmem_bytes()
    assert small.vmem_bytes() < VMEM_BYTES
