"""Pallas kernels (interpret=True) vs pure-jnp oracles in ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import attention as pallas_attention
from compile.kernels.cast_transpose import cast_transpose as pallas_ct
from compile.kernels.fp8_matmul import scaled_matmul, te_linear, us_linear
from compile.kernels.layernorm import layernorm as pallas_ln


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@pytest.mark.parametrize("fmt", ["none", "bf16", "e4m3", "e5m2"])
@pytest.mark.parametrize("shape", [(8, 16, 8), (32, 32, 32), (64, 16, 48)])
def test_scaled_matmul_matches_ref(fmt, shape):
    m, k, n = shape
    x = _rand(0, (m, k))
    w = _rand(1, (k, n))
    alpha = 1.0 / np.sqrt(k)
    got = scaled_matmul(x, w, alpha, fmt, fmt)
    want = ref.scaled_matmul(x, w, alpha, fmt, fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block_m", [8, 16, 32])
def test_scaled_matmul_tiling_invariant(block_m):
    x = _rand(2, (32, 24))
    w = _rand(3, (24, 40))
    full = scaled_matmul(x, w, 0.5, "e4m3", "e4m3", block_m=None)
    tiled = scaled_matmul(x, w, 0.5, "e4m3", "e4m3", block_m=block_m)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(tiled))


def test_scaled_matmul_mixed_formats():
    x = _rand(4, (16, 16), scale=3.0)
    w = _rand(5, (16, 16))
    got = scaled_matmul(x, w, 1.0, "e5m2", "e4m3")
    want = ref.scaled_matmul(x, w, 1.0, "e5m2", "e4m3")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("fmt", ["e4m3", "e5m2"])
@pytest.mark.parametrize("block", [None, 8, 16])
def test_cast_transpose_matches_ref(fmt, block):
    x = _rand(6, (32, 16), scale=100.0)
    q, qt = pallas_ct(x, fmt, block=block)
    rq, rqt = ref.cast_transpose(x, fmt)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(rq))
    np.testing.assert_array_equal(np.asarray(qt), np.asarray(rqt))
    np.testing.assert_array_equal(np.asarray(qt), np.asarray(q).T)


@pytest.mark.parametrize("rows,block_rows", [(8, None), (32, 8), (64, 16)])
def test_layernorm_matches_ref(rows, block_rows):
    x = _rand(7, (rows, 48), scale=5.0)
    g = _rand(8, (48,)) + 1.0
    b = _rand(9, (48,))
    got = pallas_ln(x, g, b, block_rows=block_rows)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("sqrt_softmax", [False, True])
@pytest.mark.parametrize("bhsd", [(1, 2, 16, 8), (2, 4, 32, 16)])
def test_attention_matches_ref(sqrt_softmax, bhsd):
    b, h, s, dh = bhsd
    q = _rand(10, bhsd)
    k = _rand(11, bhsd)
    v = _rand(12, bhsd)
    got = pallas_attention(q, k, v, sqrt_softmax=sqrt_softmax)
    want = ref.attention(q, k, v, sqrt_softmax=sqrt_softmax)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_attention_causality():
    """Perturbing a future token never changes past outputs."""
    b, h, s, dh = 1, 2, 16, 8
    q, k, v = _rand(13, (b, h, s, dh)), _rand(14, (b, h, s, dh)), _rand(15, (b, h, s, dh))
    base = pallas_attention(q, k, v)
    v2 = v.at[:, :, s - 1].add(100.0)
    k2 = k.at[:, :, s - 1].add(100.0)
    pert = pallas_attention(q, k2, v2)
    np.testing.assert_allclose(
        np.asarray(base[:, :, : s - 1]), np.asarray(pert[:, :, : s - 1]), rtol=1e-5, atol=1e-5
    )


def test_sqrt_softmax_variance_preserving_iid():
    """Paper Eq. 8: with iid unit-variance values, sqrt-softmax attention
    keeps per-position output std ~1 while standard attention decays."""
    key = jax.random.PRNGKey(42)
    s, dh = 256, 64
    q = jax.random.normal(key, (8, 1, s, dh))
    k = jax.random.normal(jax.random.PRNGKey(43), (8, 1, s, dh)) * (dh**-0.25)
    q = q * (dh**-0.25)  # logits ~ N(0,1)
    v = jax.random.normal(jax.random.PRNGKey(44), (8, 1, s, dh))
    std_sq = np.asarray(jnp.std(ref.attention(q, k, v, sqrt_softmax=True), axis=(0, 1, 3)))
    std_st = np.asarray(jnp.std(ref.attention(q, k, v, sqrt_softmax=False), axis=(0, 1, 3)))
    # standard: sigma(k) ~ 1/sqrt(k) -> large decay from pos 4 to 255
    assert std_st[255] < 0.35 * std_st[3]
    # sqrt-softmax: flat within a loose band
    assert 0.7 < std_sq[255] / std_sq[3] < 1.3
    assert abs(std_sq[128] - 1.0) < 0.3


def test_us_linear_exact_gradients_none_fmt():
    """With fmt=none, us_linear's custom VJP must equal autodiff exactly."""
    x = _rand(20, (8, 12))
    w = _rand(21, (12, 8))
    alpha = 0.37

    def f(x, w):
        return jnp.sum(us_linear(x, w, alpha, "none", None) ** 2)

    def f_ref(x, w):
        return jnp.sum((alpha * x @ w) ** 2)

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-5, atol=1e-6)


def test_us_linear_fp8_grad_formats():
    """FP8 backward: dx/dw computed from e5m2 grads + e4m3 operands."""
    x = _rand(22, (8, 8))
    w = _rand(23, (8, 8))
    alpha = 0.5
    g = _rand(24, (8, 8))
    _, vjp = jax.vjp(lambda x, w: us_linear(x, w, alpha, "fp8", None), x, w)
    dx, dw = vjp(g)
    rx = ref.scaled_matmul(g, w.T, alpha, "e5m2", "e4m3")
    rw = ref.scaled_matmul(x.T, g, alpha, "e4m3", "e5m2")
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rx), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(rw), rtol=1e-6)


def test_te_linear_matches_dynamic_ref():
    x = _rand(25, (16, 16), scale=0.01)  # small values: dynamic scaling rescues them
    w = _rand(26, (16, 16), scale=0.01)
    got = te_linear(x, w, "e4m3")
    want = ref.dynamic_scaled_matmul(x, w, "e4m3")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    # and the result is close to the exact matmul (that's the point of TE);
    # atol covers cancellation in near-zero dot products
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=0.2, atol=1e-4)


def test_static_fp8_underflows_small_values_but_dynamic_does_not():
    """The tradeoff the paper removes by *keeping tensors unit variance*:
    static casting destroys badly-scaled tensors; µS keeps them well-scaled."""
    x = jnp.full((8, 8), 1e-5)
    w = jnp.full((8, 8), 1e-5)
    static = scaled_matmul(x, w, 1.0, "e4m3", "e4m3")
    dynamic = te_linear(x, w, "e4m3")
    assert float(jnp.max(jnp.abs(static))) == 0.0
    np.testing.assert_allclose(np.asarray(dynamic), np.asarray(x @ w), rtol=0.1)
