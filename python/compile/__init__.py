"""Build-time compile path: L1 Pallas kernels + L2 JAX model -> HLO text."""
