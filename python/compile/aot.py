"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest.json.

Interchange is HLO text, NOT `.serialize()`: jax >= 0.5 emits HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` 0.1.6 rust crate links) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact kinds (uniform across configs; the L2<->L3 ABI):

  init       (seed:i32[])                        -> params..., momentum...
  train_step (params..., momentum..., tokens:i32[B,S],
              lr:f32[], wd:f32[], tau:f32[])     -> params..., momentum...,
                                                    loss:f32[], gnorm:f32[]
  fwd        (params..., tokens, tau)            -> logits:f32[B,S,V]
  probe      (params..., tokens, tau)            -> per-layer ProbeStats..., loss
  kernels_demo                                   -> pallas kernel showcase

Every artifact is described in artifacts/manifest.json (name, kind, config,
ordered input/output specs) so the rust runtime can pack literals without
any knowledge of the python side beyond this file's conventions.

Run: `python -m compile.aot --out-dir ../artifacts [--set core|e2e|all] [--force]`
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import HIST_NBINS, ModelConfig, param_specs
from .kernels.attention import attention as pallas_attention
from .kernels.cast_transpose import cast_transpose
from .kernels.layernorm import layernorm as pallas_layernorm

F32, I32 = "f32", "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name, shape, dtype=F32):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _state_specs(cfg, prefix=""):
    return [_spec(prefix + n, s) for n, s in param_specs(cfg)]


def _shape_structs(specs):
    dt = {F32: jnp.float32, I32: jnp.int32}
    return [jax.ShapeDtypeStruct(tuple(s["shape"]), dt[s["dtype"]]) for s in specs]


class Builder:
    def __init__(self, out_dir, force=False):
        self.out_dir = out_dir
        self.force = force
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def add(self, name, kind, fn, in_specs, out_specs, cfg=None, extra=None):
        """Lower `fn` (flat positional args per in_specs) and write HLO text."""
        if any(e["name"] == name for e in self.entries):
            return  # config appears in several experiment sets; build once
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        entry = {
            "name": name,
            "kind": kind,
            "file": fname,
            "config": cfg.to_dict() if cfg else None,
            "inputs": in_specs,
            "outputs": out_specs,
        }
        if extra:
            entry.update(extra)
        self.entries.append(entry)
        if os.path.exists(path) and not self.force:
            print(f"  [skip] {name}")
            return
        t0 = time.time()
        lowered = jax.jit(fn, keep_unused=True).lower(*_shape_structs(in_specs))
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  [ok]   {name}  ({len(text)//1024} KiB, {time.time()-t0:.1f}s)", flush=True)

    def write_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"version": 1, "artifacts": self.entries}, f, indent=1)
        print(f"manifest: {path} ({len(self.entries)} artifacts)")


def add_model_artifacts(b: Builder, cfg: ModelConfig, kinds=("init", "train_step")):
    n = cfg.name()
    pspecs = _state_specs(cfg)
    mspecs = _state_specs(cfg, "m_")
    tok = _spec("tokens", (cfg.batch, cfg.seq_len), I32)
    scalars = [_spec("lr", ()), _spec("wd", ()), _spec("tau", ())]
    nstate = len(pspecs)

    if "init" in kinds:
        def init_fn(seed):
            p, m = model.init_state(seed, cfg)
            return tuple(p) + tuple(m)

        b.add(f"init_{n}", "init", init_fn, [_spec("seed", (), I32)],
              pspecs + mspecs, cfg)

    if "train_step" in kinds:
        def step_fn(*args):
            p = list(args[:nstate])
            m = list(args[nstate : 2 * nstate])
            tokens, lr, wd, tau = args[2 * nstate :]
            p2, m2, loss, gnorm = model.train_step(p, m, tokens, lr, wd, tau, cfg)
            return tuple(p2) + tuple(m2) + (loss, gnorm)

        b.add(
            f"train_{n}", "train_step", step_fn,
            pspecs + mspecs + [tok] + scalars,
            pspecs + mspecs + [_spec("loss", ()), _spec("gnorm", ())], cfg,
        )

    if "fwd" in kinds:
        def fwd_fn(*args):
            p = list(args[:nstate])
            tokens, tau = args[nstate:]
            return model.forward(p, tokens, tau, cfg)

        b.add(
            f"fwd_{n}", "fwd", fwd_fn,
            pspecs + [tok, _spec("tau", ())],
            [_spec("logits", (cfg.batch, cfg.seq_len, cfg.vocab))], cfg,
        )

    if "probe" in kinds:
        def probe(*args):
            p = list(args[:nstate])
            tokens, tau = args[nstate:]
            return model.probe_fn(p, tokens, tau, cfg)

        L, S = cfg.depth, cfg.seq_len
        out_specs = [
            _spec("attn_std", (L, S)),
            _spec("attn_sqrt_std", (L, S)),
            _spec("vcos", (L, S)),
            _spec("resid_std", (L, S)),
            _spec("underflow", (L, 5)),
            _spec("hist_in", (L, HIST_NBINS)),
            _spec("hist_out", (L, HIST_NBINS)),
            _spec("loss", ()),
        ]
        b.add(
            f"probe_{n}", "probe", probe,
            pspecs + [tok, _spec("tau", ())], out_specs, cfg,
        )


def add_kernels_demo(b: Builder):
    """Showcase artifact: Pallas layernorm, cast_transpose, attention (std
    and sqrt-softmax) crossing the rust bridge — used by examples and
    integration tests to validate each L1 kernel end to end."""
    R, D = 64, 32
    BH, S, DH = 2, 64, 16

    def demo(x, g, bb, q, k, v):
        ln = pallas_layernorm(x, g, bb)
        ct, ctt = cast_transpose(x, "e4m3", block=16)
        q4 = q.reshape(1, BH, S, DH)
        k4 = k.reshape(1, BH, S, DH)
        v4 = v.reshape(1, BH, S, DH)
        a_std = pallas_attention(q4, k4, v4, sqrt_softmax=False)
        a_sqrt = pallas_attention(q4, k4, v4, sqrt_softmax=True)
        return ln, ct, ctt, a_std.reshape(BH, S, DH), a_sqrt.reshape(BH, S, DH)

    ins = [
        _spec("x", (R, D)), _spec("g", (D,)), _spec("b", (D,)),
        _spec("q", (BH, S, DH)), _spec("k", (BH, S, DH)), _spec("v", (BH, S, DH)),
    ]
    outs = [
        _spec("ln", (R, D)), _spec("ct", (R, D)), _spec("ctT", (D, R)),
        _spec("attn", (BH, S, DH)), _spec("attn_sqrt", (BH, S, DH)),
    ]
    b.add("kernels_demo", "kernels_demo", demo, ins, outs)


def write_goldens(out_dir):
    """Cross-layer golden vectors: ml_dtypes FP8/BF16 round-trips consumed
    bit-exactly by rust/src/fp8 unit tests."""
    vals = [
        0.0, 1.0, -1.0, 0.5, 2.0, 3.14159265, -2.71828, 448.0, 449.0, 1000.0,
        -448.0, -1000.0, 57344.0, 60000.0, 0.015625, 0.001953125, 1e-3, 1e-4,
        1e-5, -1e-5, 1e-9, 2.4e-7, 4.8e-7, 1.9e-6, 0.0009765625, 0.00048828125,
        0.000244140625, 6.1e-5, 65504.0, 3.3895e38, 1.17e-38, 7.0, 7.5, 8.5,
        13.0, 17.0, 21.0, 100.0, 240.0, 352.0, 0.1, 0.2, 0.3, 0.7, 0.9,
    ]
    x = jnp.array(vals, jnp.float32)

    def enc(v):
        """NaN/inf are invalid JSON: encode specials as strings."""
        v = float(v)
        if v != v:
            return "nan"
        if v == float("inf"):
            return "inf"
        if v == float("-inf"):
            return "-inf"
        return v

    out = {
        "input": [enc(v) for v in vals],
        "e4m3_static": [enc(v) for v in jnp.clip(x, -448, 448).astype(jnp.float8_e4m3fn).astype(jnp.float32)],
        "e5m2_static": [enc(v) for v in jnp.clip(x, -57344, 57344).astype(jnp.float8_e5m2).astype(jnp.float32)],
        "e4m3_raw": [enc(v) for v in x.astype(jnp.float8_e4m3fn).astype(jnp.float32)],
        "e5m2_raw": [enc(v) for v in x.astype(jnp.float8_e5m2).astype(jnp.float32)],
        "bf16": [enc(v) for v in x.astype(jnp.bfloat16).astype(jnp.float32)],
    }
    path = os.path.join(out_dir, "goldens.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"goldens: {path}")


# ---------------------------------------------------------------------------
# Artifact sets (see DESIGN.md §4 experiment index)

HD = 16          # proxy head_dim
V, S, B = 512, 128, 4
DBASE = 32

SWEEP_WIDTHS = [32, 64, 128, 256]           # Fig 6 (8x width transfer)
QUAD_SIZES = [(64, 4), (128, 6), (256, 8)]  # Fig 7 proxy S/M/L
DEEP = (64, 24)                              # Fig 4b / Fig 5 deep proxy
E2E = dict(width=384, depth=6, head_dim=64, vocab=2048, seq_len=256, batch=8,
           d_base=32)                        # headline driver (~12M params)


def proxy(width, depth, **kw):
    base = dict(width=width, depth=depth, head_dim=HD, vocab=V, seq_len=S,
                batch=B, d_base=DBASE)
    base.update(kw)
    return ModelConfig(**base)


def build_core(b: Builder):
    print("== sweep set (Fig 6) ==")
    for w in SWEEP_WIDTHS:
        add_model_artifacts(b, proxy(w, 4, variant="mus", precision="fp8"))
        add_model_artifacts(b, proxy(w, 4, variant="sp", precision="bf16",
                                     residual="standard"))
    print("== quad set (Fig 7 / Table 5) ==")
    for w, d in QUAD_SIZES:
        for variant in ("mus", "sp"):
            for precision in ("fp8", "bf16"):
                res = "fixed" if variant == "mus" else "standard"
                kinds = ("init", "train_step")
                if (w, d) == QUAD_SIZES[-1]:
                    kinds = ("init", "train_step", "fwd")  # Table 5 evals
                add_model_artifacts(
                    b, proxy(w, d, variant=variant, precision=precision,
                             residual=res), kinds)
    print("== probes (Fig 2/3/12) ==")
    add_model_artifacts(b, proxy(128, 6, variant="mus", precision="fp8"),
                        ("probe",))
    add_model_artifacts(b, proxy(128, 6, variant="sp", precision="bf16",
                                 residual="standard"), ("probe",))
    print("== deep set (Fig 4b / Fig 5) ==")
    w, d = DEEP
    add_model_artifacts(b, proxy(w, d, variant="mus", precision="fp8"))
    add_model_artifacts(b, proxy(w, d, variant="mus", precision="fp8",
                                 residual="running_mean"))
    add_model_artifacts(b, proxy(w, d, variant="sp", precision="bf16",
                                 residual="standard"))
    print("== activation set (Fig 11) ==")
    for act in ("gelu", "silu", "relu"):
        for precision in ("fp8", "bf16"):
            add_model_artifacts(b, proxy(64, 4, activation=act,
                                         precision=precision))
        add_model_artifacts(b, proxy(64, 4, activation=act, precision="fp8"),
                            ("probe",))
    print("== tau sweep extra depths (Fig 9) ==")
    for d in (8, 16):
        add_model_artifacts(b, proxy(64, d))
    add_kernels_demo(b)


def build_e2e(b: Builder):
    print("== e2e headline driver ==")
    for precision in ("fp8", "bf16"):
        kinds = ("init", "train_step", "fwd") if precision == "fp8" else ("init", "train_step")
        add_model_artifacts(b, ModelConfig(variant="mus", precision=precision, **E2E), kinds)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", default="all", choices=["core", "e2e", "all"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    b = Builder(args.out_dir, force=args.force)
    t0 = time.time()
    if args.set in ("core", "all"):
        build_core(b)
    if args.set in ("e2e", "all"):
        build_e2e(b)
    write_goldens(args.out_dir)
    b.write_manifest()
    print(f"total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
