"""L2: µnit-Scaled / Standard-Parametrized decoder-only transformer.

Everything the paper's Table 1 lists is implemented here:

  - static 1/sqrt(fan_in) output multipliers (1/fan_in on the LM head),
    applied in fwd *and* bwd via the Pallas `us_linear` custom VJP;
  - Res-Post-LayerNorm (µS) vs Pre-LayerNorm (SP);
  - fixed(tau) / running-mean / standard residual combination (Eq. 10/11);
  - unit-variance init (µS) vs sigma_init (SP);
  - FP8 e4m3 fwd / e5m2 bwd hidden linears, embedding + LM head in BF16;
  - per-tensor LR multipliers implementing zero-shot transfer (§2.3);
  - Lion optimizer with fully decoupled weight decay (App. A.3).

The training step is a single pure function lowered to one HLO artifact;
the rust coordinator feeds (params, momentum, tokens, lr, wd, tau) and
gets back the updated state — Python is never on the step path.
"""

from typing import List, NamedTuple

import jax
import jax.numpy as jnp

from .configs import (
    HIDDEN_PARAMS,
    HIST_LO_EXP,
    HIST_NBINS,
    ModelConfig,
    lr_mult,
    output_mult,
    param_specs,
    wd_mult,
)
from .kernels import ref
from .kernels.fp8 import quantize
from .kernels.fp8_matmul import te_linear, us_linear

# ---------------------------------------------------------------------------
# Initialization


def init_params(seed, cfg: ModelConfig) -> List[jax.Array]:
    """Initialize parameters in `param_specs` order from an i32 seed.

    µS: every linear weight (and the embedding) has unit variance —
    representability in FP8 from step 0 is the point. SP: N(0, sigma_init^2).
    """
    key = jax.random.PRNGKey(seed if isinstance(seed, int) else seed.astype(jnp.uint32))
    sigma = 1.0 if cfg.variant == "mus" else cfg.sigma_init
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.startswith("rms"):  # gain-only RMS norms start at 1
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(sigma * jax.random.normal(sub, shape, jnp.float32))
    return params


def init_state(seed, cfg: ModelConfig):
    """(params, momentum) — momentum zero-initialized, matching shapes."""
    params = init_params(seed, cfg)
    momentum = [jnp.zeros_like(p) for p in params]
    return params, momentum


# ---------------------------------------------------------------------------
# Building blocks


def _activation(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=False)
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    raise ValueError(kind)


def _linear(x2d, w, pname: str, cfg: ModelConfig):
    """Dispatch a 2-D matmul to the right L1 kernel for (variant, precision).

    All matmuls in the model flow through the Pallas kernel; only the
    quantization mode differs. Embedding table and LM head stay BF16 even
    in FP8 mode (paper Table 1).
    """
    if cfg.variant == "mus":
        alpha = output_mult(cfg, pname)
        prec = cfg.precision if pname in HIDDEN_PARAMS else "bf16"
        return us_linear(x2d, w, alpha, prec, None)
    # SP baseline
    if pname in HIDDEN_PARAMS and cfg.precision == "fp8":
        return te_linear(x2d, w, "e4m3")  # dynamic (TE-style) scaling
    return us_linear(x2d, w, 1.0, "bf16", None)


def _rope(q, k, theta: float):
    """Rotary position embedding over [B, H, S, Dh]."""
    dh = q.shape[-1]
    s = q.shape[2]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(ang)[None, None]  # [1,1,S,half]
    sin = jnp.sin(ang)[None, None]

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)

    return rot(q), rot(k)


class ProbeStats(NamedTuple):
    """Per-layer numerics probes backing Figs 2, 3, 11, 12."""

    attn_std: jax.Array       # [S]   std of softmax@V output per position
    attn_sqrt_std: jax.Array  # [S]   same with sqrt-softmax (Eq. 9)
    vcos: jax.Array           # [S]   mean cos-sim of value token i to j<i
    resid_std: jax.Array      # [S]   residual-stream std after the block
    underflow: jax.Array      # [5]   e4m3 underflow frac: block_in, qkv_out,
                              #       attn_out, act_out, block_out
    hist_in: jax.Array        # [NB]  log10 |x| histogram of block input
    hist_out: jax.Array       # [NB]  log10 |x| histogram of block output


PROBE_FIELDS = list(ProbeStats._fields)
PROBE_UNDERFLOW_TENSORS = ["block_in", "qkv_out", "attn_out", "act_out", "block_out"]


def _hist(x):
    """Normalized histogram of |x| over half-decade log10 bins."""
    edges = 10.0 ** (HIST_LO_EXP + 0.5 * jnp.arange(HIST_NBINS - 1, dtype=jnp.float32))
    idx = jnp.searchsorted(edges, jnp.abs(x).reshape(-1))
    counts = jnp.zeros((HIST_NBINS,), jnp.float32).at[idx].add(1.0)
    return counts / x.size


def _underflow(x):
    """Fraction of bf16-nonzero elements flushed to 0 by the e4m3 cast."""
    xb = x.astype(jnp.bfloat16).astype(jnp.float32)
    q = quantize(xb, "e4m3")
    nz = (xb != 0.0).astype(jnp.float32)
    under = jnp.logical_and(xb != 0.0, q == 0.0).astype(jnp.float32)
    return jnp.sum(under) / jnp.maximum(jnp.sum(nz), 1.0)


def _vcos(v):
    """Mean cosine similarity of each value token to its predecessors.

    v: [B, H, S, Dh] -> [S]. Position 0 (no predecessor) gets 0.
    """
    vn = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-12)
    c = jnp.einsum("bhsd,bhtd->bhst", vn, vn)  # [B,H,S,S]
    s = v.shape[2]
    ii = jnp.arange(s)[:, None]
    jj = jnp.arange(s)[None, :]
    mask = (jj < ii).astype(jnp.float32)  # strict predecessors
    num = jnp.sum(c * mask[None, None], axis=(0, 1, 3))
    den = jnp.maximum(jnp.sum(mask, axis=1) * v.shape[0] * v.shape[1], 1.0)
    return num / den


def _block(x, layer, coeffs, cfg: ModelConfig, probe: bool):
    """One transformer block. x: [B,S,D]. layer: tuple of per-layer params.
    coeffs: ((a1,c1),(a2,c2)) residual combination weights (Eq. 10/11)."""
    w_qkv, w_o, w_up, w_down, g1, g2 = layer
    (a1, c1), (a2, c2) = coeffs
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    stats = {}

    def attn_f(inp):
        qkv = _linear(inp.reshape(b * s, d), w_qkv, "w_qkv", cfg).reshape(b, s, 3 * d)
        qkv = quantize(qkv, "bf16")  # attention itself runs in BF16
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        k = k.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        q, k = _rope(q, k, cfg.rope_theta)
        o = ref.attention(q, k, v, sqrt_softmax=(cfg.attn_kind == "sqrt_softmax"))
        if probe:
            stats["attn_std"] = jnp.std(o, axis=(0, 1, 3))
            o_sqrt = ref.attention(q, k, v, sqrt_softmax=True)
            stats["attn_sqrt_std"] = jnp.std(o_sqrt, axis=(0, 1, 3))
            stats["vcos"] = _vcos(v)
            stats["qkv_out"] = qkv
        of = o.transpose(0, 2, 1, 3).reshape(b * s, d)
        out = _linear(of, w_o, "w_o", cfg).reshape(b, s, d)
        if probe:
            stats["attn_out"] = out
        return out

    def ffn_f(inp):
        u = _linear(inp.reshape(b * s, d), w_up, "w_up", cfg)
        a = _activation(u, cfg.activation)
        if probe:
            stats["act_out"] = a
        return _linear(a, w_down, "w_down", cfg).reshape(b, s, d)

    x_in = x
    if cfg.ln_placement == "pre":
        x = a1 * x + c1 * attn_f(ref.rmsnorm(x, g1))
        x = a2 * x + c2 * ffn_f(ref.rmsnorm(x, g2))
    else:  # res_post: the norm is the *last* op of each residual branch (Fig 4a)
        x = a1 * x + c1 * ref.rmsnorm(attn_f(x), g1)
        x = a2 * x + c2 * ref.rmsnorm(ffn_f(x), g2)

    if not probe:
        return x, None
    ps = ProbeStats(
        attn_std=stats["attn_std"],
        attn_sqrt_std=stats["attn_sqrt_std"],
        vcos=stats["vcos"],
        resid_std=jnp.std(x, axis=(0, 2)),
        underflow=jnp.stack(
            [
                _underflow(x_in),
                _underflow(stats["qkv_out"]),
                _underflow(stats["attn_out"]),
                _underflow(stats["act_out"]),
                _underflow(x),
            ]
        ),
        hist_in=_hist(x_in),
        hist_out=_hist(x),
    )
    return x, ps


def _residual_coeffs(tau, cfg: ModelConfig):
    """Residual combination weights per layer: [L, 2, 2] = (a, b) for the
    attn and ffn branches of each block.

    fixed (Eq. 10):        a = sqrt(1-tau), b = sqrt(tau)
    running-mean (Eq. 11): branch i (1-based; the embedding is
                           contribution 0): a = sqrt(i/(i+1)), b = sqrt(1/(i+1))
    standard (SP):         a = b = 1
    """
    L = cfg.depth
    if cfg.residual == "standard":
        return jnp.ones((L, 2, 2), jnp.float32)
    if cfg.residual == "fixed":
        tau = jnp.asarray(tau, jnp.float32)
        a = jnp.sqrt(1.0 - tau)
        b = jnp.sqrt(tau)
        pair = jnp.stack([a, b])
        return jnp.broadcast_to(pair[None, None, :], (L, 2, 2))
    # running-mean (Eq. 11)
    i = jnp.arange(1, 2 * L + 1, dtype=jnp.float32).reshape(L, 2)
    a = jnp.sqrt(i / (i + 1.0))
    b = jnp.sqrt(1.0 / (i + 1.0))
    return jnp.stack([a, b], axis=-1)


# ---------------------------------------------------------------------------
# Forward / loss


def forward(params: List[jax.Array], tokens, tau, cfg: ModelConfig, probe: bool = False):
    """Full forward pass. tokens: i32 [B,S]. Returns logits [B,S,V] f32
    (and stacked per-layer ProbeStats when probe=True)."""
    names = [n for n, _ in param_specs(cfg)]
    p = dict(zip(names, params))
    x = p["embed"][tokens]  # [B,S,D]; output multiplier 1 (Table 2)
    x = quantize(x, "bf16")
    coeffs = _residual_coeffs(tau, cfg)

    layer_params = (
        p["w_qkv"], p["w_o"], p["w_up"], p["w_down"],
        p["rms1_g"], p["rms2_g"],
    )

    def body(carry, xs):
        layer, cf = xs[:-1], xs[-1]
        x_new, ps = _block(
            carry, layer, ((cf[0, 0], cf[0, 1]), (cf[1, 0], cf[1, 1])), cfg, probe
        )
        return x_new, ps

    x, stats = jax.lax.scan(body, x, layer_params + (coeffs,))
    x = ref.rmsnorm(x, p["rmsf_g"])
    b, s, d = x.shape
    logits = _linear(x.reshape(b * s, d), p["head"], "head", cfg)
    logits = logits.reshape(b, s, cfg.vocab).astype(jnp.float32)
    if probe:
        return logits, stats
    return logits


def loss_fn(params, tokens, tau, cfg: ModelConfig):
    """Mean next-token cross-entropy (f32)."""
    logits = forward(params, tokens, tau, cfg)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Optimizer / train step


def train_step(params, momentum, tokens, lr, wd, tau, cfg: ModelConfig):
    """One Lion step with per-tensor transfer multipliers baked in.

    lr / wd are *base-width* values (eta at d_base, lambda); the artifact
    multiplies by the µS (or SP) transfer rule per tensor (paper §2.3).
    Returns (params', momentum', loss, grad_norm).
    """
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, tau, cfg)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    names = [n for n, _ in param_specs(cfg)]
    new_p, new_m = [], []
    for name, p, m, g in zip(names, params, momentum, grads):
        p2, m2 = ref.lion_update(
            p, m, g, lr * lr_mult(cfg, name), wd * wd_mult(cfg, name)
        )
        new_p.append(p2)
        new_m.append(m2)
    return new_p, new_m, loss, gnorm


def probe_fn(params, tokens, tau, cfg: ModelConfig):
    """Numerics probe: per-layer stats (Figs 2/3/11/12) + loss, no update."""
    logits, stats = forward(params, tokens, tau, cfg, probe=True)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    loss = jnp.mean(-jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0])
    return tuple(stats) + (loss,)
