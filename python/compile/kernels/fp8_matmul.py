"""L1 hot-spot: quantize-aware scaled GEMM as a Pallas kernel.

The paper's hidden linear layers compute (Eq. 17):

    C <- alpha * A B          with alpha = 1/sqrt(fan_in), A,B in FP8

On H100 this is a cublasLt FP8 GEMM with the static alpha folded into the
epilogue. Here the kernel round-trips both operands through the real FP8
storage format (ml_dtypes bit-exact e4m3fn / e5m2) *inside* the kernel —
the quantize+GEMM fusion the paper implements with Triton+cublasLt — and
accumulates in f32.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles for
H100 SMEM/threadblocks; on TPU the same schedule is expressed with a
BlockSpec grid over M tiles, full-K blocks resident in VMEM, MXU-aligned
(128x128) tiles. interpret=True is mandatory on this CPU-only image, so
the BlockSpec structure (not wallclock) is the optimization target.

`us_linear` wraps the kernel in a custom VJP implementing the µS backward
pass: the *same* static alpha in bwd (paper Table 1 — exact gradients),
activations/weights quantized e4m3, incoming gradients e5m2.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import FP8_E4M3_MAX, FP8_E5M2_MAX
from .fp8 import dynamic_scale

_FMT = {
    "e4m3": (jnp.float8_e4m3fn, FP8_E4M3_MAX),
    "e5m2": (jnp.float8_e5m2, FP8_E5M2_MAX),
}


def _q(x, fmt):
    """In-kernel static quantization: clip to format max, round-trip."""
    if fmt == "none":
        return x
    if fmt == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    dtype, fmax = _FMT[fmt]
    return jnp.clip(x, -fmax, fmax).astype(dtype).astype(jnp.float32)


def _matmul_kernel(x_ref, w_ref, o_ref, *, alpha, x_fmt, w_fmt):
    xq = _q(x_ref[...], x_fmt)
    wq = _q(w_ref[...], w_fmt)
    o_ref[...] = alpha * jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def scaled_matmul(x, w, alpha=1.0, x_fmt="none", w_fmt="none", block_m=None):
    """alpha * q(x) @ q(w) for 2-D x [M,K], w [K,N].

    block_m tiles the M dimension (grid over M); K and N are kept whole so
    each grid cell is one MXU-shaped GEMM with a single VMEM-resident
    weight block (weights are reused across the M grid — the schedule a
    TPU double-buffers). Default: one block (CPU interpret path).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if block_m is None or block_m >= m:
        block_m = m
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m,)
    kern = functools.partial(_matmul_kernel, alpha=alpha, x_fmt=x_fmt, w_fmt=w_fmt)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _fwd_fmts(precision):
    if precision == "fp8":
        return "e4m3", "e4m3", "e5m2"
    if precision == "bf16":
        return "bf16", "bf16", "bf16"
    return "none", "none", "none"


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def us_linear(x, w, alpha, precision="fp8", block_m=None):
    """µnit-Scaled linear: y = alpha * q_fwd(x) @ q_fwd(w).

    Backward (exact gradients, static scaling in *both* passes):
        dx = alpha * q_bwd(g) @ q_fwd(w)^T
        dw = alpha * q_fwd(x)^T @ q_bwd(g)
    with q_fwd = e4m3 round-trip, q_bwd = e5m2 round-trip ("fp8"), or bf16
    round-trips ("bf16"), or identity ("none"). alpha is a trace-time
    constant (static scaling is the point).
    """
    xf, wf, _ = _fwd_fmts(precision)
    return scaled_matmul(x, w, alpha, xf, wf, block_m)


def _us_linear_fwd(x, w, alpha, precision, block_m):
    xf, wf, _ = _fwd_fmts(precision)
    y = scaled_matmul(x, w, alpha, xf, wf, block_m)
    return y, (x, w)


def _us_linear_bwd(alpha, precision, block_m, res, g):
    x, w = res
    xf, wf, gf = _fwd_fmts(precision)
    # TN-layout story: the transposed quantized operands come from the
    # fused cast_transpose kernel on real hardware; mathematically
    # q(w)^T == q(w^T) elementwise, which is what we compute here.
    dx = scaled_matmul(g, w.T, alpha, gf, wf, block_m)
    dw = scaled_matmul(x.T, g, alpha, xf, gf, None)
    return dx, dw


us_linear.defvjp(_us_linear_fwd, _us_linear_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def te_linear(x, w, fmt="e4m3"):
    """SP+FP8 baseline linear with TransformerEngine-style *dynamic*
    (just-in-time amax) per-tensor scaling — the overhead µS removes.

        sx = max/amax(|x|); sw likewise
        y  = (q(x*sx) @ q(w*sw)) / (sx*sw)

    Backward rescales the e5m2-quantized gradient the same way.
    """
    sx = dynamic_scale(x, fmt)
    sw = dynamic_scale(w, fmt)
    y = scaled_matmul(x * sx, w * sw, 1.0, fmt, fmt)
    return y / (sx * sw)


def _te_linear_fwd(x, w, fmt):
    return te_linear(x, w, fmt), (x, w)


def _te_linear_bwd(fmt, res, g):
    x, w = res
    sg = dynamic_scale(g, "e5m2")
    sx = dynamic_scale(x, fmt)
    sw = dynamic_scale(w, fmt)
    dx = scaled_matmul(g * sg, w.T * sw, 1.0, "e5m2", fmt) / (sg * sw)
    dw = scaled_matmul(x.T * sx, g * sg, 1.0, fmt, "e5m2") / (sx * sg)
    return dx, dw


te_linear.defvjp(_te_linear_fwd, _te_linear_bwd)
