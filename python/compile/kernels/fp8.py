"""FP8 / BF16 quantization primitives (pure jnp, used inside kernels too).

µS casts are *static*: clip to the format's max, then cast — no amax
reduction (paper Table 1, "FP8 hidden layers"). The dynamic (TE-style)
path computes a just-in-time per-tensor scale and is used only by the
SP+FP8 baseline.
"""

import jax.numpy as jnp

from ..configs import FP8_E4M3_MAX, FP8_E5M2_MAX

_FMT_DTYPE = {
    "e4m3": (jnp.float8_e4m3fn, FP8_E4M3_MAX),
    "e5m2": (jnp.float8_e5m2, FP8_E5M2_MAX),
}


def quantize(x, fmt: str):
    """Round-trip `x` through a compute format.

    fmt: "e4m3" | "e5m2" — clip to dtype max then cast (static scaling)
         "bf16"          — plain bfloat16 round-trip
         "none"          — identity (f32)
    Returns an f32 tensor holding values representable in `fmt`.
    """
    if fmt == "none":
        return x
    if fmt == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    dtype, fmax = _FMT_DTYPE[fmt]
    return jnp.clip(x, -fmax, fmax).astype(dtype).astype(jnp.float32)


def dynamic_scale(x, fmt: str):
    """TransformerEngine-style just-in-time per-tensor scale factor.

    scale = fmt_max / amax(|x|), so x*scale fills the representable range.
    This amax reduction is exactly the overhead µS eliminates (paper §3.3).
    """
    _, fmax = _FMT_DTYPE[fmt]
    amax = jnp.max(jnp.abs(x))
    return fmax / jnp.maximum(amax, 1e-12)


def quantize_dynamic(x, fmt: str):
    """Quantize with a dynamic scale; returns (q, scale) with q ≈ x*scale
    representable in fmt. Caller divides the GEMM output by the scales."""
    s = dynamic_scale(x, fmt)
    return quantize(x * s, fmt), s


def underflow_fraction(x, fmt: str = "e4m3"):
    """Fraction of elements that are nonzero in bf16 but flush to zero when
    cast to `fmt` (the paper's "FP8 underflow fraction", App. A.5)."""
    xb = x.astype(jnp.bfloat16).astype(jnp.float32)
    q = quantize(xb, fmt)
    nz = xb != 0.0
    under = jnp.logical_and(nz, q == 0.0)
    return jnp.sum(under.astype(jnp.float32)) / jnp.maximum(
        jnp.sum(nz.astype(jnp.float32)), 1.0
    )
