"""Fused clip -> FP8 cast -> transpose Pallas kernel (paper §3.3).

H100 FP8 GEMMs only support the "TN" layout, so the forward pass needs W
and the backward pass needs W^T (likewise for activations/gradients). The
paper fuses clipping to the FP8 max, the cast, and the transpose into one
Triton kernel to avoid three memory round-trips. This is the TPU/Pallas
rendition: one grid pass over square tiles, each tile quantized once in
VMEM and written to both layouts.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import FP8_E4M3_MAX, FP8_E5M2_MAX

_FMT = {
    "e4m3": (jnp.float8_e4m3fn, FP8_E4M3_MAX),
    "e5m2": (jnp.float8_e5m2, FP8_E5M2_MAX),
}


def _ct_kernel(x_ref, o_ref, ot_ref, *, fmt):
    dtype, fmax = _FMT[fmt]
    q = jnp.clip(x_ref[...], -fmax, fmax).astype(dtype).astype(jnp.float32)
    o_ref[...] = q
    ot_ref[...] = q.T


def cast_transpose(x, fmt="e4m3", block=None):
    """Returns (q, qT): the FP8 round-trip of x in both layouts.

    x: [M, N] f32. block tiles both dims (square-ish tiles so the
    transposed write stays VMEM-local); default one block.
    """
    m, n = x.shape
    bm = m if block is None or block >= m else block
    bn = n if block is None or block >= n else block
    assert m % bm == 0 and n % bn == 0, (x.shape, block)
    grid = (m // bm, n // bn)
    kern = functools.partial(_ct_kernel, fmt=fmt)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((n, m), jnp.float32),
        ],
        interpret=True,
    )(x)
