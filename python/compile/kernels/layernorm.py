"""LayerNorm Pallas kernel (Res-Post-LayerNorm placement, paper §2.1).

Row-parallel: grid over blocks of rows, one full feature row per cell
(mean/var are feature-axis reductions, so the feature dim must be whole
in VMEM — same constraint as a Triton row kernel).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


def layernorm(x, g, b, eps=1e-5, block_rows=None):
    """LayerNorm over the last axis of 2-D x [R, D]; g,b: [D]."""
    r, d = x.shape
    br = r if block_rows is None or block_rows >= r else block_rows
    assert r % br == 0, (r, block_rows)
    kern = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=True,
    )(x, g, b)
