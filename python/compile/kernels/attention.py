"""Causal attention Pallas kernel with optional Square-Root Softmax (Eq. 9).

    Attention(Q,K,V) = f(softmax(Q K^T / sqrt(dh))) V,
    f = identity (standard) or sqrt (variance-preserving for iid values,
    paper Prop. 2.1 / Eq. 8-9).

Grid over (batch*heads); each cell holds one head's full [S, Dh] Q/K/V in
VMEM — a FlashAttention-style S-blocked schedule is noted in DESIGN.md §7
but the unblocked form is what interpret-mode CPU executes. Forward-only:
the training graph uses the differentiable jnp composition (attention is
BF16 in the paper; only *linear layers* are FP8), this kernel serves the
inference/probe paths and the Fig 2 analysis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, sqrt_softmax, causal):
    q = q_ref[0]  # [S, Dh]
    k = k_ref[0]
    v = v_ref[0]
    s = q.shape[0]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        ii = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        logits = jnp.where(jj <= ii, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    if sqrt_softmax:
        p = jnp.sqrt(p)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def attention(q, k, v, sqrt_softmax=False, causal=True):
    """q,k,v: [B, H, S, Dh] f32 -> [B, H, S, Dh]."""
    b, h, s, dh = q.shape
    scale = 1.0 / (dh**0.5)
    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)
    kern = functools.partial(
        _attn_kernel, scale=scale, sqrt_softmax=sqrt_softmax, causal=causal
    )
    spec = pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0))
    out = pl.pallas_call(
        kern,
        grid=(b * h,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), jnp.float32),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)
