"""Pure-jnp oracles for every Pallas kernel and for the Lion optimizer.

These are the correctness ground truth: pytest asserts each Pallas kernel
(interpret=True) against the function of the same name here, and the rust
integration tests consume goldens generated from these.
"""

import jax
import jax.numpy as jnp

from .fp8 import quantize, quantize_dynamic


def scaled_matmul(x, w, alpha=1.0, x_fmt="none", w_fmt="none"):
    """y = alpha * quantize(x) @ quantize(w), f32 accumulation."""
    xq = quantize(x, x_fmt)
    wq = quantize(w, w_fmt)
    return alpha * jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def dynamic_scaled_matmul(x, w, fmt="e4m3"):
    """TE-style: per-tensor JIT scales, GEMM on scaled values, rescale."""
    xq, sx = quantize_dynamic(x, fmt)
    wq, sw = quantize_dynamic(w, fmt)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32) / (sx * sw)


def cast_transpose(x, fmt="e4m3"):
    """Fused clip -> cast -> (value, transpose) (paper §3.3 Triton kernel).

    Returns (q, qT) where q is the format round-trip of x and qT == q.T —
    the H100 "TN" layout constraint means both layouts of the same
    quantized tensor are needed across fwd/bwd.
    """
    q = quantize(x, fmt)
    return q, q.T


def layernorm(x, g, b, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def rmsnorm(x, g, eps=1e-6):
    """Gain-only RMS norm over the last axis (the block norm of the
    transformer model; matches the rust reference runtime's epsilon)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def attention(q, k, v, sqrt_softmax=False, causal=True):
    """Causal multi-head attention. q,k,v: [B, H, S, Dh].

    sqrt_softmax=True applies Eq. 9: scores = sqrt(softmax(logits)).
    """
    dh = q.shape[-1]
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    if sqrt_softmax:
        p = jnp.sqrt(p)
    return jnp.einsum("bhst,bhtd->bhsd", p, v)


def lion_update(p, m, g, lr, wd, beta1=0.9, beta2=0.99):
    """Lion with *fully decoupled* weight decay (Wortsman et al. 2024):

        c      = beta1*m + (1-beta1)*g
        p_new  = p - lr*sign(c) - wd*p        (wd NOT multiplied by lr)
        m_new  = beta2*m + (1-beta2)*g
    """
    c = beta1 * m + (1.0 - beta1) * g
    p_new = p - lr * jnp.sign(c) - wd * p
    m_new = beta2 * m + (1.0 - beta2) * g
    return p_new, m_new
