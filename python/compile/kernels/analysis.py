"""L1 Pallas kernel structural analysis: VMEM footprint + MXU utilization.

interpret=True gives CPU-numpy timings only (NOT a TPU proxy), so the L1
performance deliverable is structural (DESIGN.md §7): for each kernel and
BlockSpec we compute

  - VMEM bytes resident per grid cell (must fit ~16 MiB/core on TPUv4),
  - MXU tile alignment (128x128 systolic array: utilization = how full the
    lane/sublane tiles are),
  - arithmetic intensity (flops / HBM byte) vs the TPU roofline knee,

and pick the TPU block shapes accordingly. Run:

    python -m compile.kernels.analysis
"""

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024  # TPUv4 per-core VMEM
MXU = 128                      # systolic array dimension
HBM_GBPS = 1200.0              # TPUv4 HBM bandwidth
BF16_TFLOPS = 275.0            # TPUv4 peak


@dataclass
class GemmTile:
    name: str
    m: int
    k: int
    n: int
    bm: int
    in_bytes: int = 1   # fp8 operands
    acc_bytes: int = 4  # f32 accumulator

    def vmem_bytes(self) -> int:
        # x block [bm,k] + w block [k,n] (resident across the M grid) +
        # out block [bm,n] f32, double-buffered input stream (x2 on x)
        return 2 * self.bm * self.k * self.in_bytes + self.k * self.n * self.in_bytes \
            + self.bm * self.n * self.acc_bytes

    def mxu_utilization(self) -> float:
        # fraction of each 128x128 MXU tile actually used
        def frac(d):
            return d / (((d + MXU - 1) // MXU) * MXU)
        return frac(self.bm) * frac(self.k) * frac(self.n)

    def arithmetic_intensity(self) -> float:
        flops = 2 * self.m * self.k * self.n
        # weights loaded once (resident), activations streamed
        bytes_moved = self.m * self.k * self.in_bytes + self.k * self.n * self.in_bytes \
            + self.m * self.n * self.acc_bytes
        return flops / bytes_moved

    def roofline_bound(self) -> str:
        knee = BF16_TFLOPS * 1e12 / (HBM_GBPS * 1e9)
        return "compute" if self.arithmetic_intensity() > knee else "memory"


def paper_scale_tiles():
    """The four hidden GEMMs at the paper's 7B shape (d=4096), tokens=8192
    per core, with the MXU-aligned block choice bm=512."""
    d, f, toks, bm = 4096, 16384, 8192, 512
    return [
        GemmTile("qkv (x @ Wqkv)", toks, d, 3 * d, bm),
        GemmTile("attn-out (o @ Wo)", toks, d, d, bm),
        GemmTile("ffn-up (x @ Wup)", toks, d, f, bm),
        GemmTile("ffn-down (a @ Wdown)", toks, f, d, bm),
    ]


def proxy_tiles():
    """The CPU-proxy shapes this repo actually runs (single block)."""
    d, f, toks = 256, 1024, 512
    return [
        GemmTile("qkv", toks, d, 3 * d, toks),
        GemmTile("ffn-down", toks, f, d, toks),
    ]


def report(tiles, title):
    lines = [title]
    lines.append(
        f"{'kernel':<22}{'block':<16}{'VMEM':>10}{'fits?':>7}{'MXU util':>10}"
        f"{'AI (fl/B)':>11}{'bound':>9}"
    )
    for t in tiles:
        # large weights (e.g. ffn-up: 4096x16384 = 64MiB) need N tiling;
        # large K (ffn-down) additionally needs a smaller M block. Shrink
        # N then M until the working set fits, keeping MXU alignment.
        bm_t, n_tile = t.bm, t.n

        def vm_of(bm_t, n_tile):
            return 2 * bm_t * t.k * t.in_bytes + t.k * n_tile * t.in_bytes \
                + bm_t * n_tile * t.acc_bytes

        vm = vm_of(bm_t, n_tile)
        while vm > VMEM_BYTES and n_tile > MXU:
            n_tile //= 2
            vm = vm_of(bm_t, n_tile)
        while vm > VMEM_BYTES and bm_t > MXU:
            bm_t //= 2
            vm = vm_of(bm_t, n_tile)
        block = f"({bm_t},{t.k})x({t.k},{n_tile})"
        lines.append(
            f"{t.name:<22}{block:<16}{vm/2**20:>8.1f}Mi{'yes' if vm <= VMEM_BYTES else 'NO':>7}"
            f"{t.mxu_utilization()*100:>9.0f}%{t.arithmetic_intensity():>11.0f}"
            f"{t.roofline_bound():>9}"
        )
    return "\n".join(lines)


def main():
    print(report(paper_scale_tiles(), "== paper 7B shape (d=4096), TPUv4 targets =="))
    print()
    print(report(proxy_tiles(), "== CPU proxy shapes (interpret=True, single block) =="))
    print(
        "\nall paper-scale hidden GEMMs are compute-bound at fp8 with MXU-aligned"
        "\nblocks; cast_transpose and layernorm are memory-bound streaming kernels"
        "\n(one pass), so their block choice only needs VMEM fit + lane alignment."
    )


if __name__ == "__main__":
    main()
