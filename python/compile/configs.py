"""Model / artifact configuration shared by model.py and aot.py.

A single `ModelConfig` describes one transformer variant at one shape. The
same dataclass is serialized into artifacts/manifest.json so the rust
coordinator (rust/src/config) can reason about shapes without re-deriving
anything from HLO.

Conventions
-----------
- `variant`   : "mus" (µnit Scaling, Res-Post-LayerNorm, unit init, static
                1/sqrt(fan_in) multipliers) or "sp" (standard parametrization,
                Pre-LayerNorm, sigma_init init, no multipliers).
- `precision` : "bf16"  — hidden matmuls in bfloat16 (mixed precision),
                "fp8"   — hidden matmuls on values round-tripped through
                          float8_e4m3fn (fwd) / float8_e5m2 (grads).
                For `sp` + `fp8`, TransformerEngine-style *dynamic* (just-in-
                time amax) per-tensor scaling is used; for `mus` + `fp8`
                scaling is *static* (the whole point of the paper).
- Runtime scalars (NOT baked): learning rate (meaning: eta at d_base),
  fully-decoupled weight decay lambda, residual coefficient tau.
- Baked at trace time: shapes, variant, activation, residual scheme,
  per-tensor LR multipliers implementing the transfer rule of paper §2.3.
"""

from dataclasses import dataclass, field, asdict
from typing import List, Tuple

FP8_E4M3_MAX = 448.0
FP8_E5M2_MAX = 57344.0

# Log10-spaced |x| histogram bin edges used by probe artifacts (Fig 12).
HIST_LO_EXP = -10
HIST_HI_EXP = 6
HIST_NBINS = (HIST_HI_EXP - HIST_LO_EXP) * 2 + 2  # half-decade bins + under/over


@dataclass(frozen=True)
class ModelConfig:
    width: int = 64
    depth: int = 4
    head_dim: int = 16
    vocab: int = 512
    seq_len: int = 128
    batch: int = 4
    ffn_ratio: int = 4
    d_base: int = 32            # base width for hyperparameter transfer
    variant: str = "mus"        # "mus" | "sp"
    precision: str = "fp8"      # "fp8" | "bf16"
    residual: str = "fixed"     # "fixed" | "running_mean" | "standard" (sp)
    activation: str = "gelu"    # "gelu" | "silu" | "relu"
    sigma_init: float = 0.02    # SP weight init stddev
    rope_theta: float = 10000.0
    # Attention score transform for the *training* graph. The paper's µS
    # models use standard softmax + Res-Post-LN; "sqrt" (Eq. 9) exists for
    # the Fig 2 analysis and is exposed for ablations.
    attn_kind: str = "softmax"  # "softmax" | "sqrt_softmax"

    @property
    def n_heads(self) -> int:
        assert self.width % self.head_dim == 0
        return self.width // self.head_dim

    @property
    def ffn_width(self) -> int:
        return self.width * self.ffn_ratio

    @property
    def ln_placement(self) -> str:
        return "res_post" if self.variant == "mus" else "pre"

    @property
    def fp8_scaling(self) -> str:
        if self.precision != "fp8":
            return "none"
        return "static" if self.variant == "mus" else "dynamic"

    def n_params(self) -> int:
        # per block: qkv + attn-out + ffn-up + ffn-down + two gain-only
        # RMS norms; plus embed, the final RMS gain, and the LM head
        # (matches rust ModelConfig::n_params and the runtime block
        # layout exactly).
        d, f, v, l = self.width, self.ffn_width, self.vocab, self.depth
        per_layer = d * 3 * d + d * d + d * f + f * d + 2 * d
        return v * d + l * per_layer + d + d * v

    def name(self) -> str:
        res = "" if self.residual == "fixed" else f"_{self.residual}"
        act = "" if self.activation == "gelu" else f"_{self.activation}"
        attn = "" if self.attn_kind == "softmax" else "_sqrtattn"
        return (
            f"{self.variant}_{self.precision}_w{self.width}_d{self.depth}"
            f"_v{self.vocab}_s{self.seq_len}_b{self.batch}{res}{act}{attn}"
        )

    def to_dict(self) -> dict:
        d = asdict(self)
        d["n_heads"] = self.n_heads
        d["ffn_width"] = self.ffn_width
        d["ln_placement"] = self.ln_placement
        d["fp8_scaling"] = self.fp8_scaling
        d["n_params"] = self.n_params()
        return d


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical parameter ordering.

    This ordering is the L2<->L3 ABI: rust/src/runtime packs and unpacks
    literals strictly in this order. Per-layer tensors are stacked on a
    leading depth axis and consumed with lax.scan.
    """
    d, f, v, l = cfg.width, cfg.ffn_width, cfg.vocab, cfg.depth
    return [
        ("embed", (v, d)),
        ("w_qkv", (l, d, 3 * d)),
        ("w_o", (l, d, d)),
        ("w_up", (l, d, f)),
        ("w_down", (l, f, d)),
        ("rms1_g", (l, d)),
        ("rms2_g", (l, d)),
        ("rmsf_g", (d,)),
        ("head", (d, v)),
    ]


# Parameter groups for per-tensor transfer rules (paper §2.3 / Table 2).
HIDDEN_PARAMS = ("w_qkv", "w_o", "w_up", "w_down")
DECAY_PARAMS = ("embed", "w_qkv", "w_o", "w_up", "w_down", "head")


def lr_mult(cfg: ModelConfig, pname: str) -> float:
    """Per-tensor multiplier on the runtime lr input (which means eta at
    d_base). Bakes the zero-shot transfer rule into the artifact."""
    if cfg.variant == "mus":
        if pname in HIDDEN_PARAMS:
            return (cfg.d_base / cfg.width) ** 0.5
        return 1.0
    # SP: eta_new = eta_base * d_base / d_new for all layers (paper §3.2).
    return cfg.d_base / cfg.width


def wd_mult(cfg: ModelConfig, pname: str) -> float:
    """Fully-decoupled weight-decay multiplier. µS: lambda transfers
    unchanged (Table 1). SP's empirical 0.5x jump at transfer is a policy
    decision applied by the rust scaling module, not baked here."""
    if pname in DECAY_PARAMS:
        return 1.0
    return 0.0


def output_mult(cfg: ModelConfig, pname: str) -> float:
    """Static output multipliers (Table 2). fan_in of each matmul."""
    if cfg.variant != "mus":
        return 1.0
    d, f = cfg.width, cfg.ffn_width
    fan_in = {"w_qkv": d, "w_o": d, "w_up": d, "w_down": f}
    if pname in fan_in:
        return 1.0 / fan_in[pname] ** 0.5
    if pname == "head":
        return 1.0 / d  # LM head multiplier 1/fan_in, in line with µP
    return 1.0
